//! Concurrency primitives behind a std/loom switch.
//!
//! Every lock-free or lock-protected structure that has (or may grow) a
//! `cfg(loom)` model imports its primitives from here instead of
//! `std::sync`.  In a normal build the re-exports are zero-cost aliases
//! of the std types; under `RUSTFLAGS="--cfg loom"` they resolve to the
//! `loom` model-checker's instrumented doubles, so the same source is
//! exercised under exhaustive interleaving in the
//! `#[cfg(all(loom, test))]` models (see ARCHITECTURE.md, "Static
//! analysis & concurrency checking").
//!
//! Process-global statics (samplers, registries) intentionally stay on
//! `std::sync` even under loom: loom primitives may only live inside a
//! `loom::model` run, and the models only ever exercise per-instance
//! state.

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Arc, Mutex, MutexGuard};

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex, MutexGuard};

/// Lock a mutex, recovering from poisoning: serving-path consumers
/// (scheduler, engines, stats endpoint, trace export) must keep working
/// after some thread panicked mid-update — for these structures a torn
/// update is strictly better than a dead serving loop.  The rrs-audit
/// lint (rule R2) rejects `.lock().unwrap()` on the serving path; this
/// is the sanctioned replacement.
pub fn lock_recover<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// `fetch_min` over an `AtomicU32`.  Loom does not model the min/max
/// RMW intrinsics, so under `cfg(loom)` this degrades to a CAS loop —
/// semantically identical, and still fully interleaving-checked.
#[inline]
pub fn fetch_min_u32(a: &AtomicU32, v: u32, order: Ordering) -> u32 {
    #[cfg(not(loom))]
    {
        a.fetch_min(v, order)
    }
    #[cfg(loom)]
    {
        let mut cur = a.load(Ordering::Relaxed);
        while v < cur {
            match a.compare_exchange_weak(cur, v, order, Ordering::Relaxed) {
                Ok(prev) => return prev,
                Err(next) => cur = next,
            }
        }
        cur
    }
}

/// `fetch_max` over an `AtomicU32`; see [`fetch_min_u32`].
#[inline]
pub fn fetch_max_u32(a: &AtomicU32, v: u32, order: Ordering) -> u32 {
    #[cfg(not(loom))]
    {
        a.fetch_max(v, order)
    }
    #[cfg(loom)]
    {
        let mut cur = a.load(Ordering::Relaxed);
        while v > cur {
            match a.compare_exchange_weak(cur, v, order, Ordering::Relaxed) {
                Ok(prev) => return prev,
                Err(next) => cur = next,
            }
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_recover_returns_inner_after_poison() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn fetch_min_max_track_extremes() {
        let a = AtomicU32::new(100);
        fetch_min_u32(&a, 40, Ordering::Relaxed);
        fetch_min_u32(&a, 70, Ordering::Relaxed);
        assert_eq!(a.load(Ordering::Relaxed), 40);
        let b = AtomicU32::new(0);
        fetch_max_u32(&b, 9, Ordering::Relaxed);
        fetch_max_u32(&b, 3, Ordering::Relaxed);
        assert_eq!(b.load(Ordering::Relaxed), 9);
    }
}
