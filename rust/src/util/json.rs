//! Minimal JSON parser/serializer (replaces `serde_json`).
//!
//! Covers the subset the repo needs: manifest.json, qa_tasks.json,
//! profiles.json, config files and report output.  Numbers are f64,
//! objects preserve insertion order (Vec of pairs) so emitted reports
//! diff cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(xs) => xs.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kvs) => Some(kvs),
            _ => None,
        }
    }

    /// Object entries as a map (for order-insensitive lookups).
    pub fn to_map(&self) -> BTreeMap<String, &Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().map(|(k, v)| (k.clone(), v)).collect(),
            _ => BTreeMap::new(),
        }
    }

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// Convenience builder: `obj([("a", 1.0.into())])`.
pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
    Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf-8".to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.ws();
            xs.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Json::parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(v.idx(1).unwrap().idx(0).unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{key: 1}").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("123abc").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_dump_clean() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }
}
