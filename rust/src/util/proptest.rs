//! Mini property-testing driver (replaces `proptest`): run a property over
//! many seeded random cases; on failure, report the failing seed so the
//! case is reproducible, and retry with "smaller" sizes to aid debugging.

use super::rng::Pcg;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xc0ffee }
    }
}

/// Run `prop(rng, case_index)`; panics with the failing seed on error.
/// The property returns `Err(msg)` to fail.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Pcg, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Pcg::new(case_seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case} (seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Helper: assert two f32 slices are close; returns Err for `check`.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", Config::default(), |rng, _| {
            let a = rng.normal();
            let b = rng.normal();
            if (a + b - (b + a)).abs() < 1e-9 {
                Ok(())
            } else {
                Err("non-commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failure() {
        check(
            "always-fails",
            Config { cases: 3, seed: 1 },
            |_, _| Err("nope".into()),
        );
    }

    #[test]
    fn close_helper() {
        assert!(assert_close(&[1.0], &[1.0001], 1e-3, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 0.0).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
