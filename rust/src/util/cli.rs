//! Tiny argv parser (replaces `clap`): `--key value`, `--flag`, positionals.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed() {
        let a = parse("serve --port 9000 --verbose --group=128 extra");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("port"), Some("9000"));
        assert_eq!(a.get_usize("group", 0), 128);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f32("f", 1.5), 1.5);
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse("--flag --k v");
        assert!(a.has_flag("flag"));
        assert_eq!(a.get("k"), Some("v"));
    }
}
