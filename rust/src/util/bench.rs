//! Micro-benchmark substrate (replaces `criterion`): warmup, timed
//! iterations, robust summary.  Used by `cargo bench` targets (harness =
//! false) and the Figure-6 kernel-efficiency harness.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// One benchmark measurement: per-iteration wall time in nanoseconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub ns: Summary,
}

impl BenchResult {
    pub fn ns_per_iter(&self) -> f32 {
        self.ns.p50
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12.0} ns/iter (p10 {:>10.0}, p90 {:>10.0}, n={})",
            self.name, self.ns.p50, self.ns.p10, self.ns.p90, self.iters
        )
    }
}

/// Benchmark driver: targets `min_duration` of measurement after warmup,
/// batching the closure so per-sample timing overhead is amortized.
pub struct Bencher {
    pub warmup: Duration,
    pub min_duration: Duration,
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(100),
            min_duration: Duration::from_millis(400),
            max_samples: 50,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(20),
            min_duration: Duration::from_millis(120),
            max_samples: 20,
        }
    }

    /// Run `f` repeatedly; returns the per-iteration timing summary.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup + estimate batch size
        let w0 = Instant::now();
        let mut batch = 0usize;
        while w0.elapsed() < self.warmup || batch == 0 {
            f();
            batch += 1;
        }
        let per_call = self.warmup.as_nanos() as f32 / batch as f32;
        // target ~ min_duration/max_samples per sample
        let target_ns =
            (self.min_duration.as_nanos() as f32 / self.max_samples as f32).max(1.0);
        let batch = ((target_ns / per_call.max(1.0)).ceil() as usize).max(1);

        let mut samples = Vec::with_capacity(self.max_samples);
        let t0 = Instant::now();
        while t0.elapsed() < self.min_duration && samples.len() < self.max_samples {
            let s = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(s.elapsed().as_nanos() as f32 / batch as f32);
        }
        BenchResult {
            name: name.to_string(),
            iters: samples.len() * batch,
            ns: Summary::of(&samples),
        }
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Where `BENCH_*.json` artifacts go: the repository root (the directory
/// holding ROADMAP.md), found by walking up from the crate dir — so
/// `cargo bench` run from `rust/` and CI steps run from the checkout
/// root write and diff the same files.  Falls back to the bare name
/// (current directory) when no marker is found.
pub fn bench_output_path(name: &str) -> std::path::PathBuf {
    let start = std::env::var_os("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .or_else(|| std::env::current_dir().ok())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let mut dir = start;
    loop {
        if dir.join("ROADMAP.md").exists() {
            return dir.join(name);
        }
        if !dir.pop() {
            return std::path::PathBuf::from(name);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters > 0);
        assert!(r.ns.p50 >= 0.0);
    }

    #[test]
    fn slower_is_slower() {
        // black_box the loop bound so the optimizer cannot const-fold
        let b = Bencher::quick();
        let fast = b.run("fast", || {
            let n = black_box(10u64);
            black_box((0..n).map(black_box).sum::<u64>());
        });
        let slow = b.run("slow", || {
            let n = black_box(10_000u64);
            black_box((0..n).map(black_box).sum::<u64>());
        });
        assert!(slow.ns.p50 > fast.ns.p50);
    }
}
