//! Scoped thread pool for data-parallel loops (replaces `rayon`'s
//! `par_chunks_mut` for the GEMM hot path and eval sweeps).
//!
//! `parallel_for` splits `[0, n)` into contiguous ranges and runs the body
//! on `std::thread::scope` workers.  On a single-core host (this CI image)
//! it degrades to the serial loop with no thread spawn.

/// Number of worker threads to use (respects `RRS_THREADS`).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("RRS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `[0, n)` into at most `threads` contiguous non-empty ranges that
/// cover it disjointly.  Pure — the piece of the pool the loom model and
/// the partition tests exercise without spawning OS threads.
pub fn partition(n: usize, threads: usize) -> Vec<std::ops::Range<usize>> {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        return vec![0..n];
    }
    let chunk = n.div_ceil(threads);
    let mut out = Vec::with_capacity(threads);
    for t in 0..threads {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        if lo >= hi {
            break;
        }
        out.push(lo..hi);
    }
    out
}

/// Run `body(range)` over a partition of `[0, n)` across `threads` workers.
/// `body` must be `Sync` (called concurrently on disjoint ranges).
pub fn parallel_for<F>(n: usize, threads: usize, body: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let ranges = partition(n, threads);
    if ranges.len() <= 1 {
        body(0..n);
        return;
    }
    std::thread::scope(|s| {
        for r in ranges {
            let body = &body;
            s.spawn(move || body(r));
        }
    });
}

/// Map `f` over disjoint mutable row-chunks of `out` in parallel; each chunk
/// is `row_len` elements and corresponds to row index `i`.
pub fn parallel_rows<T: Send, F>(out: &mut [T], row_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(out.len() % row_len.max(1), 0);
    let n = if row_len == 0 { 0 } else { out.len() / row_len };
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for (i, row) in out.chunks_mut(row_len).enumerate() {
            f(i, row);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0usize;
        for _ in 0..threads {
            let take = chunk.min(rest.len() / row_len - 0);
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take * row_len);
            rest = tail;
            let f = &f;
            let base = start;
            s.spawn(move || {
                for (j, row) in head.chunks_mut(row_len).enumerate() {
                    f(base + j, row);
                }
            });
            start += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices() {
        let hits = AtomicUsize::new(0);
        parallel_for(1000, 4, |r| {
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn serial_fallback() {
        let hits = AtomicUsize::new(0);
        parallel_for(10, 1, |r| {
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn rows_all_written() {
        let mut out = vec![0.0f32; 12 * 8];
        parallel_rows(&mut out, 8, 3, |i, row| {
            for x in row.iter_mut() {
                *x = i as f32;
            }
        });
        for (i, row) in out.chunks(8).enumerate() {
            assert!(row.iter().all(|&x| x == i as f32));
        }
    }

    #[test]
    fn zero_n_ok() {
        parallel_for(0, 4, |r| assert!(r.is_empty()));
    }

    #[test]
    fn partition_covers_disjointly() {
        for n in [0usize, 1, 7, 64, 1000, 1001] {
            for threads in [1usize, 2, 3, 8, 2000] {
                let ranges = partition(n, threads);
                assert!(ranges.len() <= threads.max(1));
                let mut next = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, next, "gap/overlap at n={n} t={threads}");
                    assert!(r.end >= r.start);
                    next = r.end;
                }
                assert_eq!(next, n, "partition must cover [0, {n})");
            }
        }
    }
}

/// Loom smoke model: workers consuming a [`partition`] concurrently
/// account for every index exactly once (the pool's disjoint-coverage
/// contract, checked across interleavings with the shim atomics).
#[cfg(all(loom, test))]
mod loom_tests {
    use super::partition;
    use crate::util::sync::{AtomicUsize, Ordering};
    use loom::thread;
    use std::sync::Arc;

    #[test]
    fn workers_cover_all_indices_once() {
        loom::model(|| {
            let n = 5usize;
            let covered = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = partition(n, 2)
                .into_iter()
                .map(|r| {
                    let covered = Arc::clone(&covered);
                    thread::spawn(move || {
                        covered.fetch_add(r.len(), Ordering::Relaxed);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(covered.load(Ordering::Relaxed), n);
        });
    }
}
