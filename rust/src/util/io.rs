//! `.rrsw` tensor container (mirror of python/compile/io_rrsw.py).
//!
//! The interchange format between the python compile path and the rust
//! runtime: trained weights, golden test vectors, learned rotations.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 6] = b"RRSW1\n";

/// Raw tensor payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I8(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn code(&self) -> u8 {
        match self {
            Data::F32(_) => 0,
            Data::I8(_) => 1,
            Data::I32(_) => 2,
            Data::U8(_) => 3,
        }
    }
}

/// Named n-dimensional tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: Data::F32(data) }
    }

    pub fn i8(shape: Vec<usize>, data: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: Data::I8(data) }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got code {}", other.code()),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            Data::I8(v) => Ok(v),
            other => bail!("expected i8 tensor, got code {}", other.code()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got code {}", other.code()),
        }
    }

    /// Shape as (rows, cols) for 2-D tensors.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        if self.shape.len() != 2 {
            bail!("expected 2-D tensor, shape {:?}", self.shape);
        }
        Ok((self.shape[0], self.shape[1]))
    }
}

/// Read a `.rrsw` file into name -> tensor.
pub fn read_rrsw(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 6];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u16(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf-8")?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let (code, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let count: usize = shape.iter().product::<usize>().max(1);
        let data = match code {
            0 => {
                let mut buf = vec![0u8; count * 4];
                r.read_exact(&mut buf)?;
                Data::F32(
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            1 => {
                let mut buf = vec![0u8; count];
                r.read_exact(&mut buf)?;
                Data::I8(buf.into_iter().map(|b| b as i8).collect())
            }
            2 => {
                let mut buf = vec![0u8; count * 4];
                r.read_exact(&mut buf)?;
                Data::I32(
                    buf.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            3 => {
                let mut buf = vec![0u8; count];
                r.read_exact(&mut buf)?;
                Data::U8(buf)
            }
            c => bail!("unknown dtype code {c}"),
        };
        out.insert(name, Tensor { shape, data });
    }
    Ok(out)
}

/// Write name -> tensor as `.rrsw` (sorted by name, like the python side).
pub fn write_rrsw(
    path: impl AsRef<Path>,
    tensors: &BTreeMap<String, Tensor>,
) -> Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        w.write_all(&(name.len() as u16).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&[t.data.code(), t.shape.len() as u8])?;
        for d in &t.shape {
            w.write_all(&(*d as u32).to_le_bytes())?;
        }
        match &t.data {
            Data::F32(v) => {
                for x in v {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            Data::I8(v) => {
                let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
                w.write_all(&bytes)?;
            }
            Data::I32(v) => {
                for x in v {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            Data::U8(v) => w.write_all(v)?,
        }
    }
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        m.insert("b".into(), Tensor::i8(vec![4], vec![-7, 0, 3, 7]));
        m.insert(
            "c".into(),
            Tensor { shape: vec![2], data: Data::I32(vec![-1, 2]) },
        );
        let dir = std::env::temp_dir().join("rrsw_test_roundtrip.rrsw");
        write_rrsw(&dir, &m).unwrap();
        let back = read_rrsw(&dir).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("rrsw_test_badmagic.rrsw");
        std::fs::write(&dir, b"NOTRRSWxxxx").unwrap();
        assert!(read_rrsw(&dir).is_err());
        std::fs::remove_file(dir).ok();
    }
}
