//! PCG64-based PRNG with normal/uniform sampling (replaces `rand`).
//!
//! Deterministic across platforms; used by tests, workload generators and
//! the Monte-Carlo harnesses (Fig. 2b / Fig. 8).

/// PCG-XSH-RR 64/32 with 64-bit output composed from two draws.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        let mut pcg = Pcg { state: 0, inc: (seed << 1) | 1 };
        pcg.next_u32();
        pcg.state = pcg.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        pcg.next_u32();
        pcg
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let mut u1 = self.uniform();
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(3);
        let xs = r.normal_vec(50_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Pcg::new(9);
        let mut got = r.choose_distinct(100, 50);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 50);
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg::new(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
