//! Environment substrates: the crates we would normally pull from
//! crates.io (rand, serde_json, criterion, proptest, clap, npy) rebuilt
//! small, because this build environment vendors only the `xla` crate.

pub mod bench;
pub mod cli;
pub mod io;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
