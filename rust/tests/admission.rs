//! Prefix-aware admission accounting: a request is charged only for its
//! *unshared* suffix blocks (plus one decode-headroom block), hit blocks
//! are excluded from the eviction supply they would pin, and the
//! reservation-time re-check inside `try_prefill` keeps same-round
//! admission races safe.  Uses small random models only (no artifacts).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use rrs::coordinator::{Coordinator, SchedulerConfig};
use rrs::kvpool::PagedEngine;
use rrs::model::sampler::Sampling;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};

fn engine(n_blocks: usize, block_size: usize) -> PagedEngine {
    let cfg = ModelConfig { n_layers: 2, max_seq: 256, ..Default::default() };
    let w = Weights::random(&cfg, 17);
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV4,
        group: 32,
        kv_group: 32,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
    PagedEngine::new(model, n_blocks, block_size)
}

fn shared_prefix(n: usize) -> Vec<u32> {
    (0..n as u32).map(|i| (i * 13 + 5) % 256).collect()
}

/// The headline accounting win: a 90%-prefix-shared prompt is admitted
/// into a pool that only has room for its unshared suffix, where the
/// conservative (whole-prompt) gate would have refused.
#[test]
fn shared_prompt_admitted_into_suffix_sized_gap() {
    let eng = engine(12, 4);
    // seed: 36 shared + 3 unique tokens, kept ACTIVE so its 10 blocks
    // (9 sealed + 1 tail) are pinned and exactly 2 blocks stay free
    let mut prompt_a = shared_prefix(36);
    prompt_a.extend([1, 2, 3]);
    let mut seq_a = eng.new_seq();
    let _ = eng.try_prefill(&mut seq_a, &prompt_a).expect("prefill");
    let s = eng.stats();
    assert_eq!(s.blocks_active, 10);
    assert_eq!(s.blocks_free, 2);

    // request B: same 36-token prefix (9 full blocks resident), 4 unique
    // tokens.  Charged blocks_for(41) - 9 = 2, which fits the gap; the
    // whole-prompt charge of 11 blocks would not.
    let mut prompt_b = shared_prefix(36);
    prompt_b.extend([200, 201, 202, 203]);
    assert_eq!(eng.prefix_match_len(&prompt_b), 36);
    assert!(
        eng.can_admit(&prompt_b),
        "prefix-aware gate must charge only the unshared suffix"
    );
    let mut seq_b = eng.new_seq();
    let logits = eng.try_prefill(&mut seq_b, &prompt_b);
    assert!(logits.is_some(), "admitted request must reserve successfully");
    assert_eq!(eng.stats().blocks_free, 0);
    eng.release(&mut seq_b);
    eng.release(&mut seq_a);
}

/// ...and the same request is refused when even the suffix does not fit,
/// with the failed reservation leaking nothing.
#[test]
fn shared_prompt_refused_when_suffix_does_not_fit() {
    let eng = engine(11, 4);
    let mut prompt_a = shared_prefix(36);
    prompt_a.extend([1, 2, 3]);
    let mut seq_a = eng.new_seq();
    let _ = eng.try_prefill(&mut seq_a, &prompt_a).expect("prefill");
    assert_eq!(eng.stats().blocks_free, 1);

    let mut prompt_b = shared_prefix(36);
    prompt_b.extend([200, 201, 202, 203]);
    assert!(!eng.can_admit(&prompt_b), "2-block suffix cannot fit 1 block");
    // the reservation-time re-check agrees and unwinds cleanly
    let mut seq_b = eng.new_seq();
    assert!(eng.try_prefill(&mut seq_b, &prompt_b).is_none());
    let s = eng.stats();
    assert_eq!(s.blocks_active, 10, "failed admission must release its pins");
    assert_eq!(s.blocks_free, 1);
    eng.release(&mut seq_a);
}

/// Evictable cached blocks that the prompt itself would pin must not be
/// double-counted as both reusable prefix and eviction supply.
#[test]
fn evictable_hits_are_not_double_counted() {
    let eng = engine(10, 4);
    let mut prompt_a = shared_prefix(36);
    prompt_a.extend([1, 2, 3]);
    let mut seq_a = eng.new_seq();
    let _ = eng.try_prefill(&mut seq_a, &prompt_a).expect("prefill");
    eng.release(&mut seq_a);
    // 9 sealed blocks cached (evictable), 1 free
    let s = eng.stats();
    assert_eq!(s.blocks_cached, 9);
    assert_eq!(s.blocks_free, 1);

    // charged 2 blocks; naive supply says free(1) + cached(9) = 10, but
    // pinning the 9 hits leaves only 1 allocatable block
    let mut prompt_b = shared_prefix(36);
    prompt_b.extend([200, 201, 202, 203]);
    assert!(!eng.can_admit(&prompt_b));
    let mut seq_b = eng.new_seq();
    assert!(eng.try_prefill(&mut seq_b, &prompt_b).is_none());
    // with one more block of headroom the same prompt fits exactly
    let eng2 = engine(11, 4);
    let mut seq_c = eng2.new_seq();
    let _ = eng2.try_prefill(&mut seq_c, &prompt_a).expect("prefill");
    eng2.release(&mut seq_c);
    assert!(eng2.can_admit(&prompt_b));
    let mut seq_d = eng2.new_seq();
    assert!(eng2.try_prefill(&mut seq_d, &prompt_b).is_some());
    eng2.release(&mut seq_d);
}

/// Lazy partial-tail adoption and admission stay consistent: the gate
/// budgets one allocatable block for the deferred CoW copy of a
/// mid-block tail, the reservation-time re-check refuses (cleanly) when
/// that block is missing, and a refused request pays zero row copies —
/// the whole point of deferring the copy from match time to first
/// append.
#[test]
fn lazy_tail_cow_block_is_budgeted_and_deferred() {
    let eng = engine(3, 4);
    let mut prompt_a = shared_prefix(6);
    prompt_a.extend([1, 2]); // 8 tokens = exactly 2 sealed blocks
    let mut seq_a = eng.new_seq();
    let _ = eng.try_prefill(&mut seq_a, &prompt_a).expect("prefill");
    eng.release(&mut seq_a);
    let s = eng.stats();
    assert_eq!((s.blocks_cached, s.blocks_free), (2, 1));

    // refusal: 6 shared tokens (1 full block + 2 tail rows) + 5 unique
    // needs 2 fresh blocks beyond the shared pair PLUS the CoW block —
    // one more than the pool holds once the hits are pinned
    let mut prompt_c = prompt_a[..6].to_vec();
    prompt_c.extend([240, 241, 242, 243, 244]);
    assert!(!eng.can_admit(&prompt_c), "gate must charge the CoW block");
    let mut seq_c = eng.new_seq();
    assert!(eng.try_prefill(&mut seq_c, &prompt_c).is_none());
    let s = eng.stats();
    assert_eq!((s.blocks_cached, s.blocks_free), (2, 1), "clean unwind");
    assert_eq!(s.lazy_tail_shares, 1);
    assert_eq!(s.lazy_tail_copies, 0, "refused request copies nothing");
    assert_eq!(s.cow_copies, 0);

    // success: a 7-token relative fits (table reuses the shared pair,
    // the single free block serves the deferred copy at first append)
    let mut prompt_b = prompt_a[..6].to_vec();
    prompt_b.push(250);
    assert!(eng.can_admit(&prompt_b));
    let mut seq_b = eng.new_seq();
    assert!(eng.try_prefill(&mut seq_b, &prompt_b).is_some());
    let s = eng.stats();
    assert_eq!(s.lazy_tail_shares, 2);
    assert_eq!(s.lazy_tail_copies, 1, "first append materialized the copy");
    assert_eq!(s.cow_copies, 1);
    // the CoW unpinned the sealed tail: it is cached again, while the
    // sequence now owns the hit block and the fresh copy
    assert_eq!((s.blocks_active, s.blocks_cached, s.blocks_free), (2, 1, 0));
    eng.release(&mut seq_b);
}

/// End-to-end through the coordinator: six concurrent requests sharing a
/// 24-token prefix all fit a 20-block pool (8 + 5 x 2 blocks), which a
/// flat per-request charge (6 x 8 = 48 blocks) could never admit
/// concurrently.
#[test]
fn coordinator_admits_shared_prefix_fleet_concurrently() {
    let cfg = ModelConfig { n_layers: 2, max_seq: 256, ..Default::default() };
    let w = Weights::random(&cfg, 17);
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV4,
        group: 32,
        kv_group: 32,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
    let coord = Arc::new(Coordinator::start(
        PagedEngine::new(model, 20, 4),
        SchedulerConfig { max_batch: 6, queue_capacity: 16, ..Default::default() },
    ).expect("start coordinator"));
    let mut handles = Vec::new();
    for i in 0..6u32 {
        let c = coord.clone();
        let mut prompt = shared_prefix(24);
        prompt.extend([100 + 4 * i, 101 + 4 * i, 102 + 4 * i, 103 + 4 * i]);
        handles.push(std::thread::spawn(move || {
            c.generate(prompt, 4, Sampling::Greedy, None).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.tokens.len(), 4);
        assert_eq!(
            resp.finish_reason,
            rrs::coordinator::request::FinishReason::MaxTokens
        );
    }
    assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 6);
    assert_eq!(coord.metrics.aborted.load(Ordering::Relaxed), 0);
    assert!(
        coord.metrics.prefix_hit_rate() > 0.3,
        "shared prefixes must be served from the cache (rate {})",
        coord.metrics.prefix_hit_rate()
    );
}
