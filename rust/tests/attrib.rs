//! Active-observability integration: per-request phase attribution under
//! multi-threaded churn (the components must sum to the attributed total
//! and never exceed wall time), the `attrib`/`profile` TCP command
//! schemas over `server::handle_line`, and the quant-drift watchdog
//! raising and clearing per-layer alerts on an injected outlier-spike
//! workload while staying silent on a clean one.
//!
//! Attribution, profiler, and watchdog state are process-global; every
//! layer label here is unique to this binary and the invariants checked
//! hold for *all* scheduler-produced rows, so the tests stay safe under
//! the default parallel test runner.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

use rrs::coordinator::{server, Coordinator, RustServeEngine, SchedulerConfig};
use rrs::linalg::gemm::Mat;
use rrs::model::sampler::Sampling;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::obs::{attrib, health, profile, watchdog};
use rrs::quant::{Method, Scheme};
use rrs::util::rng::Pcg;

const CHURN_THREADS: usize = 16;
const REQS_PER_THREAD: usize = 3;

fn tiny_coord() -> Arc<Coordinator> {
    let cfg = ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() };
    let w = Weights::random(&cfg, 42);
    let ecfg = EngineConfig {
        method: Method::Rrs,
        scheme: Scheme::A4W4KV16,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
    Arc::new(Coordinator::start(
        RustServeEngine::new(model),
        SchedulerConfig { max_batch: 4, ..Default::default() },
    ).expect("start coordinator"))
}

/// Quantize `x` per-token and feed it through the sampled-probe path
/// (the same route production GEMMs take into the watchdog).
fn probe(layer: &str, x: &Mat) {
    let (q, _s) = rrs::quant::rtn::quant_per_token(x);
    health::probe_quant(layer, x, &q);
}

/// 8×256 Gaussian activations: flat channels, kurtosis ≈ 3.
fn clean_mat(rng: &mut Pcg) -> Mat {
    Mat::from_vec(8, 256, rng.normal_vec(8 * 256))
}

/// Same, with one channel spiking to 300: the paper's outlier taxonomy,
/// far past the watchdog's relative *and* absolute margins.
fn spiky_mat(rng: &mut Pcg) -> Mat {
    let mut x = clean_mat(rng);
    for i in 0..8 {
        x.data[i * 256 + 5] = 300.0;
    }
    x
}

#[test]
fn attribution_components_sum_under_churn() {
    // profiler on for the whole churn so the `profile` command below
    // has live stacks to sample
    profile::start_at(500.0);
    let coord = tiny_coord();
    let mut joins = Vec::new();
    for t in 0..CHURN_THREADS as u32 {
        let c = coord.clone();
        joins.push(std::thread::spawn(move || {
            for r in 0..REQS_PER_THREAD as u32 {
                c.generate(vec![3 + t, 7 + r, 11], 4, Sampling::Greedy, None)
                    .unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // the Done frame can race the retire bookkeeping by a scheduler
    // round; wait for every row to land in the attribution window
    let want = CHURN_THREADS * REQS_PER_THREAD;
    let deadline = Instant::now() + Duration::from_secs(10);
    while attrib::finished_len() < want && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }

    let stop = AtomicBool::new(false);
    let reply = server::handle_line(r#"{"cmd": "attrib", "n": 256}"#, &coord, &stop);
    assert!(reply.get("window").unwrap().as_usize().unwrap() >= want);
    let rows = reply.get("requests").unwrap().as_arr().unwrap();
    assert!(rows.len() >= want, "attrib window has {} rows", rows.len());
    for row in rows {
        let total = row.get("total_ms").unwrap().as_f64().unwrap();
        let attributed = row.get("attributed_ms").unwrap().as_f64().unwrap();
        assert!(row.get("tokens").unwrap().as_usize().unwrap() >= 1);
        assert!(row.get("finish").unwrap().as_str().is_some());
        let phases = row.get("phases_ms").unwrap();
        let mut sum = 0.0;
        for p in attrib::ALL_PHASES {
            let v = phases.get(p.name()).unwrap().as_f64().unwrap();
            assert!(v >= 0.0, "{} negative: {v}", p.name());
            sum += v;
        }
        // components are exactly the attributed total...
        assert!(
            (sum - attributed).abs() < 0.5,
            "phase sum {sum} != attributed {attributed}"
        );
        // ...and attribution never invents time the request didn't
        // spend (queue/prefill/decode intervals are disjoint; the slack
        // covers clock jitter and double-counted socket writes)
        assert!(
            attributed <= total * 1.15 + 10.0,
            "over-attribution: {attributed}ms of {total}ms in {}",
            row.dump()
        );
    }

    // the profiler saw the run: schema-valid body with folded stacks
    let prof = server::handle_line(r#"{"cmd": "profile"}"#, &coord, &stop);
    profile::pause();
    assert!(prof.get("hz").unwrap().as_f64().unwrap() > 0.0);
    assert!(prof.get("samples").unwrap().as_usize().unwrap() > 0);
    assert!(prof.get("held").unwrap().as_usize().is_some());
    assert!(prof.get("dropped").unwrap().as_usize().is_some());
    let folded = prof.get("folded").unwrap().as_str().unwrap();
    assert!(!folded.is_empty(), "no folded stacks");
    for line in folded.lines() {
        let (stack, count) = line.rsplit_once(' ').expect("stack and count");
        assert!(stack.starts_with("rrs"), "bad stack root: {line}");
        assert!(count.parse::<u64>().is_ok(), "bad count: {line}");
    }
    drop(coord); // Drop joins the worker; shutdown(self) can't move out of the Arc
}

#[test]
fn watchdog_raises_and_clears_on_outlier_spike_workload() {
    let mut rng = Pcg::new(321);
    let layer = "attrib-wd-spiky";
    let key = format!("quant.{layer}.spike_ratio");

    // clean baseline: EWMAs converge, nothing fires
    for _ in 0..20 {
        probe(layer, &clean_mat(&mut rng));
    }
    assert!(
        !watchdog::active_alerts().iter().any(|k| k.contains(layer)),
        "clean baseline must not alert"
    );

    // outlier spike: fast EWMA blows through slow·rel + abs
    for _ in 0..20 {
        probe(layer, &spiky_mat(&mut rng));
    }
    let active = watchdog::active_alerts();
    assert!(active.iter().any(|k| k == &key), "no spike alert in {active:?}");
    let j = watchdog::alerts_json();
    let listed = j.get("active").unwrap().as_arr().unwrap();
    assert!(listed.iter().any(|k| k.as_str() == Some(key.as_str())), "{}", j.dump());
    let entry = j.get("alerts").unwrap().get(&key).unwrap();
    assert_eq!(entry.get("active").unwrap().as_bool(), Some(true));
    assert!(
        entry.get("value").unwrap().as_f64().unwrap()
            > entry.get("threshold").unwrap().as_f64().unwrap()
    );

    // recovery: fast decays back under the (halved) clear margin
    for _ in 0..200 {
        probe(layer, &clean_mat(&mut rng));
    }
    let active = watchdog::active_alerts();
    assert!(
        !active.iter().any(|k| k.contains(layer)),
        "alert failed to clear: {active:?}"
    );
    // the registry remembers the raise edge after the clear
    let alerts = watchdog::alerts();
    let (_, st) = alerts.iter().find(|(k, _)| k == &key).expect("alert entry");
    assert!(st.raised_total >= 1 && !st.active);
}

#[test]
fn watchdog_quiet_on_clean_workload() {
    let mut rng = Pcg::new(654);
    let layer = "attrib-wd-clean";
    for _ in 0..40 {
        probe(layer, &clean_mat(&mut rng));
    }
    let fired: Vec<String> = watchdog::alerts()
        .into_iter()
        .map(|(k, _)| k)
        .filter(|k| k.contains(layer))
        .collect();
    assert!(fired.is_empty(), "clean workload created alert entries: {fired:?}");
}
