//! Golden-vector tests: the rust quant library vs the python jnp oracle
//! (python/compile/kernels/ref.py), through artifacts/goldens.rrsw.
//!
//! These pin the cross-language numerics: per-token INT4, Hadamard
//! rotation, Runtime-Smooth GEMM (group 1 and 32), QuaRot, RRS,
//! SmoothQuant, sub-channel GEMM, KV fake-quant, the smoothness statistic
//! and GPTQ.  Requires `make artifacts`.

use std::collections::BTreeMap;

use rrs::linalg::gemm::Mat;
use rrs::linalg::igemm::MatI8;
use rrs::quant::qlinear::{PrepareAux, PrepareOpts, PreparedWeight, QLinear};
use rrs::quant::{
    gptq, kv, qlinear, rotation::Rotation, rtn, runtime_smooth, smoothquant,
    Method, QuantRecipe, Scheme,
};
use rrs::util::io::{read_rrsw, Tensor};
use rrs::util::stats;

fn goldens() -> Option<BTreeMap<String, Tensor>> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/goldens.rrsw");
    read_rrsw(path).ok()
}

fn mat(t: &Tensor) -> Mat {
    let (r, c) = t.dims2().unwrap();
    Mat::from_vec(r, c, t.as_f32().unwrap().to_vec())
}

fn mati8(t: &Tensor) -> MatI8 {
    let (r, c) = t.dims2().unwrap();
    MatI8::from_vec(r, c, t.as_i8().unwrap().to_vec())
}

fn assert_close(got: &[f32], want: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        let err = (g - w).abs();
        if err > tol {
            panic!("{what}: idx {i}: got {g}, want {w} (err {err} > tol {tol})");
        }
        worst = worst.max(err);
    }
    eprintln!("{what}: max err {worst}");
}

macro_rules! need_goldens {
    () => {
        match goldens() {
            Some(g) => g,
            None => {
                eprintln!("skipping: artifacts/goldens.rrsw missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn quant_per_token_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let (q, s) = rtn::quant_per_token(&x);
    let want_q = g["quant_q"].as_i8().unwrap();
    let n_diff = q.data.iter().zip(want_q).filter(|(a, b)| a != b).count();
    // rounding-mode ties may flip a handful of codes
    assert!(
        n_diff * 1000 <= q.data.len(),
        "{} of {} codes differ",
        n_diff,
        q.data.len()
    );
    assert_close(&s, g["quant_s"].as_f32().unwrap(), 1e-7, 1e-5, "quant scales");
}

#[test]
fn hadamard_rotation_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let got = Rotation::Hadamard.apply(&x);
    assert_close(
        &got.data,
        g["rotate"].as_f32().unwrap(),
        1e-3,
        1e-4,
        "rotate",
    );
}

#[test]
fn gemm_fp_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let w = mat(&g["w"]);
    let got = rrs::linalg::gemm::gemm_f32_bt(&x, &w);
    assert_close(&got.data, g["gemm_fp"].as_f32().unwrap(), 1e-2, 1e-4, "gemm_fp");
}

#[test]
fn gemm_rtn_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let (wq, sw) = (mati8(&g["wq"]), g["sw"].as_f32().unwrap().to_vec());
    let got = qlinear::forward_per_channel_a4w4(&x, &wq, &sw);
    assert_close(
        &got.data,
        g["gemm_rtn"].as_f32().unwrap(),
        0.5,
        5e-3,
        "gemm_rtn",
    );
}

#[test]
fn gemm_rs_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let (wq, sw) = (mati8(&g["wq"]), g["sw"].as_f32().unwrap().to_vec());
    for (group, key) in [(1usize, "gemm_rs_g1"), (32, "gemm_rs_g32")] {
        let sa = runtime_smooth::prepare(&x, group);
        let got = qlinear::forward_rs_fused(&sa, &wq, &sw);
        assert_close(
            &got.data,
            g[key].as_f32().unwrap(),
            0.5,
            5e-3,
            key,
        );
    }
}

#[test]
fn gemm_quarot_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let xr = Rotation::Hadamard.apply(&x);
    let (wq, sw) = (mati8(&g["wq_rot"]), g["sw_rot"].as_f32().unwrap().to_vec());
    let got = qlinear::forward_per_channel_a4w4(&xr, &wq, &sw);
    assert_close(
        &got.data,
        g["gemm_quarot"].as_f32().unwrap(),
        0.5,
        5e-3,
        "gemm_quarot",
    );
}

#[test]
fn gemm_rrs_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let xr = Rotation::Hadamard.apply(&x);
    let (wq, sw) = (mati8(&g["wq_rot"]), g["sw_rot"].as_f32().unwrap().to_vec());
    let sa = runtime_smooth::prepare(&xr, 32);
    let got = qlinear::forward_rs_fused(&sa, &wq, &sw);
    assert_close(
        &got.data,
        g["gemm_rrs_g32"].as_f32().unwrap(),
        0.5,
        5e-3,
        "gemm_rrs_g32",
    );
}

#[test]
fn gemm_sub_channel_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let w = mat(&g["w"]);
    let got = qlinear::forward_sub_channel_a4w4(&x, &w, 32);
    assert_close(
        &got.data,
        g["gemm_sub"].as_f32().unwrap(),
        0.5,
        5e-3,
        "gemm_sub",
    );
}

#[test]
fn smoothquant_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let w = mat(&g["w"]);
    let calib = smoothquant::Calibration::from_batches([&x].into_iter(), x.cols);
    let s = smoothquant::smoothing_scales(&calib, &w, 0.5);
    assert_close(&s, g["sq_scales"].as_f32().unwrap(), 1e-5, 1e-4, "sq scales");
    let xs = smoothquant::smooth_activation(&x, &s);
    let wm = smoothquant::merge_into_weight(&w, &s);
    let (wq, sw) = rtn::quant_per_channel_w(&wm);
    let got = qlinear::forward_per_channel_a4w4(&xs, &wq, &sw);
    assert_close(&got.data, g["gemm_sq"].as_f32().unwrap(), 0.5, 5e-3, "gemm_sq");
}

/// Strategy equivalence on golden weights: a [`QLinear`] assembled from
/// a parsed recipe descriptor and the python-quantized golden codes must
/// reproduce both the staged pre-refactor RRS pipeline (bit-for-bit)
/// and the python oracle output (within golden tolerance).
#[test]
fn recipe_layer_matches_hardcoded_rrs_on_goldens() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let (wq, sw) = (mati8(&g["wq_rot"]), g["sw_rot"].as_f32().unwrap().to_vec());
    // pre-refactor hardcoded RRS serving path: Hadamard rotate, runtime
    // smooth at group 32, fused INT4 GEMM over the permuted weight
    let xr = Rotation::Hadamard.apply(&x);
    let sa = runtime_smooth::prepare(&xr, 32);
    let want = qlinear::forward_rs_fused(&sa, &wq, &sw);
    // composable pipeline: same codes behind a parsed recipe descriptor
    let recipe = QuantRecipe::parse("rrs:a4w4kv16:g32:nogptq").unwrap();
    let layer = QLinear::from_parts(
        recipe,
        PreparedWeight::Int4 { q: wq, packed: None, scales: sw },
        None,
        Some(Rotation::Hadamard),
    );
    let got = layer.forward(&x);
    assert_eq!(
        got.data, want.data,
        "recipe pipeline diverged from the hardcoded RRS path"
    );
    assert_close(
        &got.data,
        g["gemm_rrs_g32"].as_f32().unwrap(),
        0.5,
        5e-3,
        "recipe vs golden gemm_rrs_g32",
    );
}

/// Full-prepare equivalence on golden weights: preparing the fp golden
/// weight through the legacy [`Method`] surface and through
/// [`QLinear::prepare_recipe`] yields bit-identical forwards for the
/// headline RRS W4A4 recipe.
#[test]
fn recipe_prepare_matches_method_prepare_on_goldens() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let w = mat(&g["w"]);
    let legacy = QLinear::prepare(
        &w,
        &PrepareOpts {
            method: Method::Rrs,
            scheme: Scheme::A4W4KV16,
            group: 32,
            alpha: 0.5,
            calib: None,
            gptq_calib: None,
            rotation: Some(Rotation::Hadamard),
        },
    )
    .unwrap();
    let recipe = QuantRecipe::parse("rrs:a4w4kv16:g32:nogptq").unwrap();
    let composed = QLinear::prepare_recipe(
        &w,
        &recipe,
        PrepareAux { rotation: Some(Rotation::Hadamard), ..Default::default() },
    )
    .unwrap();
    let (a, b) = (legacy.forward(&x), composed.forward(&x));
    assert_eq!(a.data, b.data, "method-prepared vs recipe-prepared RRS forward");
}

#[test]
fn kv_fake_quant_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let mut got = x.clone();
    for i in 0..got.rows {
        kv::fake_quant_inplace(got.row_mut(i), 32);
    }
    assert_close(
        &got.data,
        g["kv_fq_g32"].as_f32().unwrap(),
        1e-4,
        1e-3,
        "kv_fq_g32",
    );
}

#[test]
fn smoothness_mu_matches() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let got: Vec<f32> = (0..x.rows).map(|i| stats::smoothness_mu(x.row(i))).collect();
    assert_close(
        &got,
        g["smooth_mu"].as_f32().unwrap(),
        1e-3,
        1e-3,
        "smooth_mu",
    );
}

#[test]
fn gptq_matches_python() {
    let g = need_goldens!();
    let x = mat(&g["x"]);
    let w = mat(&g["w"]);
    // python: gptq_quantize(gw, gx) with damp=0.01, block=64
    let (q, s) = gptq::gptq_quantize(&w, &x, 0.01, 64).unwrap();
    assert_close(&s, g["gptq_sw"].as_f32().unwrap(), 1e-6, 1e-4, "gptq scales");
    let want_q = g["gptq_wq"].as_i8().unwrap();
    let n_diff = q.data.iter().zip(want_q).filter(|(a, b)| a != b).count();
    // f32-vs-f64 Hessian accumulation can flip borderline codes; demand
    // near-identity and equal *quality*
    assert!(
        n_diff * 50 <= q.data.len(),
        "{} of {} GPTQ codes differ",
        n_diff,
        q.data.len()
    );
    let e_rust = gptq::layer_error(&w, &q, &s, &x);
    let want_codes = MatI8::from_vec(w.rows, w.cols, want_q.to_vec());
    let e_py = gptq::layer_error(&w, &want_codes, g["gptq_sw"].as_f32().unwrap(), &x);
    assert!(
        e_rust <= e_py * 1.2 + 1e-6,
        "rust gptq error {e_rust} vs python {e_py}"
    );
}
