//! Observability layer integration: histogram accuracy against exact
//! quantiles, Prometheus exposition grammar over a live server, trace
//! ring behavior, and an end-to-end serve run asserting request spans +
//! per-layer quant health land in the snapshot.
//!
//! Sampling discipline: the sampling period is process-global, so tests
//! here only ever *raise* it to "every call" (`set_sample_every(1)`) and
//! never disable it — a parallel test must not see sampling switched off
//! under its feet.

use std::sync::atomic::AtomicBool;

use rrs::coordinator::{server, Coordinator, RustServeEngine, SchedulerConfig};
use rrs::model::sampler::Sampling;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::obs::hist::LogHistogram;
use rrs::obs::trace::{SpanKind, TraceRing};
use rrs::quant::{Method, Scheme};
use rrs::util::json::Json;
use rrs::util::rng::Pcg;
use rrs::util::stats;

fn tiny_coord(method: Method) -> Coordinator {
    let cfg = ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() };
    let w = Weights::random(&cfg, 42);
    let ecfg = EngineConfig {
        method,
        scheme: Scheme::A4W4KV16,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
    Coordinator::start(RustServeEngine::new(model), SchedulerConfig::default())
        .expect("start coordinator")
}

#[test]
fn histogram_percentiles_within_bucket_error_bound() {
    // log-uniform latencies over 3.6 decades: the histogram's geometric
    // interpolation must track the exact sort-based percentile within
    // one bucket ratio (10^(1/20) ~ 12%; assert 15% for headroom)
    let mut rng = Pcg::new(4242);
    let h = LogHistogram::new();
    let mut vals = Vec::with_capacity(20_000);
    for _ in 0..20_000 {
        let v = 10f32.powf(rng.range(-0.3, 3.3));
        vals.push(v);
        h.observe(v);
    }
    for p in [10.0, 50.0, 90.0, 99.0] {
        let exact = stats::percentile(&vals, p);
        let est = h.percentile(p);
        let rel = (est - exact).abs() / exact;
        assert!(rel < 0.15, "p{p}: est {est} vs exact {exact} (rel {rel:.3})");
    }
    // mean is tracked exactly (sum, not buckets)
    let mean = vals.iter().sum::<f32>() / vals.len() as f32;
    let s = h.summary();
    assert!((s.mean - mean).abs() / mean < 0.01, "mean {} vs {mean}", s.mean);
    assert_eq!(s.n, 20_000);
}

#[test]
fn histogram_concurrent_observers() {
    // lock-free claim: concurrent observers never lose counts
    let h = std::sync::Arc::new(LogHistogram::new());
    let mut handles = Vec::new();
    for t in 0..4 {
        let hh = h.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..10_000 {
                hh.observe(1.0 + ((t * 10_000 + i) % 100) as f32);
            }
        }));
    }
    for j in handles {
        j.join().unwrap();
    }
    assert_eq!(h.count(), 40_000);
    assert_eq!(h.cumulative(4).last().unwrap().1, 40_000);
}

#[test]
fn trace_ring_wraparound_keeps_newest_window() {
    let r = TraceRing::new(32);
    for i in 0..100u64 {
        r.span(i, SpanKind::DecodeStep, 10, i);
    }
    assert_eq!(r.len(), 32);
    assert_eq!(r.total(), 100);
    assert_eq!(r.dropped(), 68);
    let ids: Vec<u64> = r.events().iter().map(|e| e.req).collect();
    assert_eq!(ids, (68..100).collect::<Vec<u64>>());
    // the Chrome document stays parseable across the wrap
    let doc = r.chrome_trace_json();
    assert_eq!(doc.get("traceEvents").unwrap().as_arr().unwrap().len(), 32);
}

#[test]
fn prom_exposition_grammar_from_live_server() {
    rrs::obs::set_sample_every(1);
    let coord = tiny_coord(Method::Rrs);
    for i in 0..3u32 {
        coord
            .generate(vec![5 + i, 9, 13], 6, Sampling::Greedy, None)
            .unwrap();
    }
    // a hostile layer label must render escaped, not break the format
    {
        use rrs::linalg::gemm::Mat;
        let mut rng = Pcg::new(9);
        let x = Mat::from_vec(4, 32, rng.normal_vec(4 * 32));
        let (q, _s) = rrs::quant::rtn::quant_per_token(&x);
        rrs::obs::health::probe_quant("weird\"layer\\n", &x, &q);
    }
    let stop = AtomicBool::new(false);
    let reply = server::handle_line(r#"{"cmd": "metrics_prom"}"#, &coord, &stop);
    assert_eq!(
        reply.get("content_type").unwrap().as_str(),
        Some("text/plain; version=0.0.4")
    );
    let text = reply.get("body").unwrap().as_str().unwrap().to_string();

    // every sample line must satisfy the shared exposition parser (same
    // grammar scrapers apply), and the reply reports the malformed count
    let (samples, malformed) = rrs::obs::prom::parse_exposition(&text);
    assert_eq!(malformed, 0, "malformed exposition lines in:\n{text}");
    assert!(!samples.is_empty(), "exposition rendered no samples");
    assert_eq!(reply.get("malformed_lines").and_then(Json::as_usize), Some(0));

    // every family used by a sample line must carry a # TYPE header
    let mut declared = std::collections::HashSet::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            declared.insert(rest.split(' ').next().unwrap().to_string());
        }
    }
    for (metric, _value) in &samples {
        let name = metric.split('{').next().unwrap();
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|b| declared.contains(*b))
            .unwrap_or(name);
        assert!(declared.contains(base), "sample without TYPE header: {metric}");
    }
    // served requests put real data behind the new families
    for needle in [
        "rrs_ttft_ms_bucket",
        "rrs_itl_ms_count",
        "rrs_requests_completed_total 3",
        "rrs_quant_channel_max",
        "layer=\"weird\\\"layer\\\\n\"",
        "rrs_phase_ms_bucket",
        "rrs_slo_burn_rate{slo=\"ttft\"}",
    ] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
    coord.shutdown();
}

#[test]
fn e2e_serve_records_spans_and_quant_health() {
    rrs::obs::set_sample_every(1);
    let coord = tiny_coord(Method::Rrs);
    let (id, rx) = coord
        .submit(vec![11, 22, 33], 5, Sampling::Greedy, None)
        .unwrap();
    let resp = rrs::coordinator::request::wait_done(&rx).unwrap();
    assert_eq!(resp.tokens.len(), 5);

    // quant-health probes landed under the engine's layer labels
    let snap = coord.metrics.snapshot_json();
    let health = snap.get("quant_health").unwrap();
    let rrs::util::json::Json::Obj(layers) = health else {
        panic!("quant_health must be an object");
    };
    let l0: Vec<&String> =
        layers.iter().map(|(k, _)| k).filter(|k| k.starts_with("l0.")).collect();
    assert!(!l0.is_empty(), "no l0.* layer in quant_health: {:?}", layers);
    let (_, first) = layers.iter().find(|(k, _)| k.starts_with("l0.")).unwrap();
    assert!(first.get("probes").unwrap().as_usize().unwrap() >= 1);
    assert!(first.get("channel_max").unwrap().as_f64().unwrap() > 0.0);
    assert!(first.get("clip_rate").unwrap().as_f64().unwrap() >= 0.0);

    // the request's lifecycle is in the trace ring: enqueue -> admit ->
    // prefill -> ... -> finish, all on the request's own track
    let events = coord.metrics.trace.events();
    let mine: Vec<_> = events.iter().filter(|e| e.req == id).collect();
    for kind in
        [SpanKind::Enqueue, SpanKind::Admit, SpanKind::Prefill, SpanKind::Finish]
    {
        assert!(
            mine.iter().any(|e| e.kind == kind),
            "missing {kind:?} for req {id}: {mine:?}"
        );
    }
    let prefill =
        mine.iter().find(|e| e.kind == SpanKind::Prefill).unwrap();
    let finish = mine.iter().find(|e| e.kind == SpanKind::Finish).unwrap();
    assert_eq!(prefill.tokens, 3, "prefill span carries the prompt length");
    assert_eq!(finish.tokens, 5, "finish span carries the generated length");
    assert!(finish.ts_us >= prefill.ts_us);

    // trace TCP command round-trips the same lifecycle in Chrome format
    let stop = AtomicBool::new(false);
    let doc = server::handle_line(r#"{"cmd": "trace"}"#, &coord, &stop);
    let arr = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let tid = id as usize;
    let names: Vec<&str> = arr
        .iter()
        .filter(|e| e.get("tid").unwrap().as_usize() == Some(tid))
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    assert!(names.contains(&"prefill") && names.contains(&"finish"), "{names:?}");
    let jsonl = server::handle_line(
        r#"{"cmd": "trace", "format": "jsonl"}"#,
        &coord,
        &stop,
    );
    let body = jsonl.get("body").unwrap().as_str().unwrap();
    for line in body.lines() {
        rrs::util::json::Json::parse(line).unwrap();
    }

    // watchdog + attribution sections ride along in the snapshot
    let alerts = snap.get("alerts").unwrap();
    assert!(alerts.get("active").unwrap().as_arr().is_some());
    for k in ["ttft", "itl"] {
        let slo = alerts.get("slo").unwrap().get(k).unwrap();
        assert!(slo.get("burn_rate").is_some(), "missing slo.{k}.burn_rate");
        assert!(slo.get("threshold_ms").is_some(), "missing slo.{k}.threshold_ms");
    }
    assert!(snap.get("attrib").unwrap().get("window").unwrap().as_usize().is_some());

    // snapshot carries the new latency sections with data
    assert!(snap.get("ttft_ms").unwrap().get("n").unwrap().as_usize().unwrap() >= 1);
    assert!(snap.get("itl_ms").unwrap().get("n").unwrap().as_usize().unwrap() >= 1);
    assert!(
        snap.get("trace").unwrap().get("events_total").unwrap().as_usize().unwrap()
            >= 4
    );
    coord.shutdown();
}
