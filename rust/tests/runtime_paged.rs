//! The PJRT serving path through the paged KV pool: bit-identity with
//! the flat round-tripped cache, prefix sharing across requests, and a
//! coordinator run over the AOT backend — proving both engines sit
//! behind one pool-governed scheduler.
//!
//! Requires `make artifacts` (skips cleanly otherwise).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use rrs::coordinator::{Coordinator, SchedulerConfig};
use rrs::model::sampler::Sampling;
use rrs::runtime::{PagedPjrtEngine, PjrtEngine};

fn artifacts_root() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn have_artifacts() -> bool {
    std::path::Path::new(artifacts_root()).join("manifest.json").exists()
}

macro_rules! need_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
    };
}

fn argmax_i32(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// The acceptance gate: the same prompt served through pool blocks must
/// produce logits bit-identical to the flat `PjrtKvState` path at every
/// position — the pool stores the graph's f32 rows verbatim, so the
/// gathered dense cache equals the round-tripped one bit-for-bit.
#[test]
fn pjrt_paged_serving_bit_identical_to_flat_state() {
    need_artifacts!();
    let prompt: Vec<u32> = vec![97, 114, 108, 111, 32, 105, 115];
    let steps = 6usize;

    // flat reference: one monolithic KV state round-tripped per step
    let flat = PjrtEngine::new(artifacts_root()).unwrap();
    let b = flat.artifacts.decode_batch;
    let vocab = flat.artifacts.model.vocab;
    let mut state = flat.new_kv_state();
    let mut flat_logits: Vec<Vec<f32>> = Vec::new();
    for &t in &prompt {
        let lg = flat.decode_step("fp", &vec![t as i32; b], &mut state).unwrap();
        flat_logits.push(lg[..vocab].to_vec());
    }
    for _ in 0..steps {
        let t = argmax_i32(flat_logits.last().unwrap());
        let lg = flat.decode_step("fp", &vec![t; b], &mut state).unwrap();
        flat_logits.push(lg[..vocab].to_vec());
    }

    // paged path: same prompt, KV rows authoritative in pool blocks
    let paged = PagedPjrtEngine::new(artifacts_root(), "fp", 64, 4).unwrap();
    let mut seq = paged.new_seq();
    let mut paged_logits: Vec<Vec<f32>> =
        vec![paged.try_prefill(&mut seq, &prompt).unwrap().unwrap()];
    for _ in 0..steps {
        let t = argmax_i32(paged_logits.last().unwrap()) as u32;
        let mut batch = [(&mut seq, t)];
        let lg = paged.decode(&mut batch).unwrap();
        paged_logits.push(lg.row(0).to_vec());
    }

    // the flat loop logged every prompt position; the paged prefill only
    // returns the last one — compare from there on
    let flat_tail = &flat_logits[prompt.len() - 1..];
    assert_eq!(flat_tail.len(), paged_logits.len());
    for (step, (a, b)) in flat_tail.iter().zip(&paged_logits).enumerate() {
        assert_eq!(a.len(), b.len());
        for (j, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "step {step} logit {j}: {x} vs {y} (not bit-identical)"
            );
        }
    }
}

/// Prefix sharing on the AOT path: a second request with a shared prompt
/// prefix reuses pooled rows, and — because the rows are the graph's own
/// f32 output stored verbatim — its logits equal a cold run bit-for-bit.
#[test]
fn pjrt_paged_prefix_hit_matches_cold_run() {
    need_artifacts!();
    let shared: Vec<u32> = (0..12u32).map(|i| 40 + (i * 7) % 80).collect();
    let mut prompt_a = shared.clone();
    prompt_a.extend([65, 66, 67]);
    let mut prompt_b = shared.clone();
    prompt_b.extend([80, 81]);

    let cold = PagedPjrtEngine::new(artifacts_root(), "fp", 64, 4).unwrap();
    let mut seq_cold = cold.new_seq();
    let cold_logits = cold.try_prefill(&mut seq_cold, &prompt_b).unwrap().unwrap();

    let warm = PagedPjrtEngine::new(artifacts_root(), "fp", 64, 4).unwrap();
    let mut seq_a = warm.new_seq();
    let _ = warm.try_prefill(&mut seq_a, &prompt_a).unwrap().unwrap();
    warm.release(&mut seq_a);
    assert!(warm.prefix_match_len(&prompt_b) >= 12 / 4 * 4);
    let before = warm.stats();
    let mut seq_b = warm.new_seq();
    let warm_logits = warm.try_prefill(&mut seq_b, &prompt_b).unwrap().unwrap();
    let after = warm.stats();
    assert!(
        after.prefix_hit_tokens > before.prefix_hit_tokens,
        "prompt_b should hit the shared prefix"
    );
    for (j, (&x, &y)) in cold_logits.iter().zip(&warm_logits).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "logit {j}: {x} vs {y} (prefix hit changed the numerics)"
        );
    }
}

/// The coordinator drives the AOT backend through the same ServeEngine
/// trait: concurrent shared-prefix requests complete with pool-governed
/// admission and a warm prefix cache.
#[test]
fn coordinator_serves_pjrt_paged_backend() {
    need_artifacts!();
    let engine = PagedPjrtEngine::new(artifacts_root(), "fp", 96, 4).unwrap();
    let coord = Arc::new(Coordinator::start(
        engine,
        SchedulerConfig { max_batch: 4, queue_capacity: 16, ..Default::default() },
    ).expect("start coordinator"));
    let shared: Vec<u32> = (0..12u32).map(|i| 40 + (i * 5) % 80).collect();
    let mut handles = Vec::new();
    for i in 0..6u32 {
        let c = coord.clone();
        let mut prompt = shared.clone();
        prompt.extend([97 + i, 98 + i]);
        handles.push(std::thread::spawn(move || {
            c.generate(prompt, 4, Sampling::Greedy, None).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.tokens.len(), 4);
    }
    assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 6);
    assert!(
        coord.metrics.prefix_hit_tokens.load(Ordering::Relaxed) > 0,
        "prefix cache never hit on the PJRT paged backend"
    );
}
