//! Coordinator integration: continuous batching over the rust engine,
//! backpressure, metrics, TCP server protocol, and the paged KV-pool
//! backend (prefix sharing + scheduler preemption).  Uses a small random
//! model (no artifacts needed) so it runs in any checkout.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use rrs::coordinator::{server, Coordinator, RustServeEngine, SchedulerConfig};
use rrs::kvpool::PagedEngine;
use rrs::model::sampler::Sampling;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};

fn tiny_model(method: Method, scheme: Scheme) -> QuantModel {
    let cfg = ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() };
    let w = Weights::random(&cfg, 42);
    let calib: Vec<u32> = (0..128u32).map(|i| (i * 53 + 7) % 256).collect();
    let ecfg = EngineConfig {
        method,
        scheme,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    QuantModel::prepare(&w, &cfg, &ecfg, Some(&calib), None).unwrap()
}

fn tiny_engine(method: Method, scheme: Scheme) -> RustServeEngine {
    RustServeEngine::new(tiny_model(method, scheme))
}

#[test]
fn single_request_roundtrip() {
    let coord = Coordinator::start(
        tiny_engine(Method::Rrs, Scheme::A4W4KV4),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    let resp = coord
        .generate(vec![10, 20, 30], 8, Sampling::Greedy, None)
        .unwrap();
    assert_eq!(resp.tokens.len(), 8);
    assert!(resp.total_ms >= 0.0);
    assert!(resp.prefill_ms > 0.0);
    coord.shutdown();
}

#[test]
fn concurrent_requests_all_complete() {
    let coord = Arc::new(Coordinator::start(
        tiny_engine(Method::Rtn, Scheme::A4W4KV4),
        SchedulerConfig { max_batch: 4, ..Default::default() },
    ).expect("start coordinator"));
    let mut handles = Vec::new();
    for i in 0..12u32 {
        let c = coord.clone();
        handles.push(std::thread::spawn(move || {
            c.generate(vec![1 + i, 2, 3], 6, Sampling::Greedy, None).unwrap()
        }));
    }
    let mut ids = Vec::new();
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.tokens.len(), 6);
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 12, "every request got a distinct response");
    assert_eq!(
        coord
            .metrics
            .completed
            .load(std::sync::atomic::Ordering::Relaxed),
        12
    );
    // continuous batching actually batched: fewer decode steps than
    // sequential execution would need (12 reqs x 5 steps each)
    let steps = coord
        .metrics
        .decode_steps
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(steps < 12 * 5, "decode steps {steps} suggest no batching");
}

#[test]
fn stop_token_terminates_early() {
    let coord = Coordinator::start(
        tiny_engine(Method::Fp, Scheme::FP),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    // stop on whatever token greedy emits first: run once to find it
    let probe = coord
        .generate(vec![5, 6], 4, Sampling::Greedy, None)
        .unwrap();
    let first = probe.tokens[0];
    let resp = coord
        .generate(vec![5, 6], 16, Sampling::Greedy, Some(first))
        .unwrap();
    assert_eq!(resp.tokens.len(), 1);
    assert_eq!(
        resp.finish_reason,
        rrs::coordinator::request::FinishReason::StopToken
    );
    coord.shutdown();
}

#[test]
fn prompt_too_long_rejected() {
    let coord = Coordinator::start(
        tiny_engine(Method::Fp, Scheme::FP),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    let long: Vec<u32> = vec![1; 200];
    let err = coord.generate(long, 8, Sampling::Greedy, None).unwrap_err();
    assert!(matches!(
        err,
        rrs::coordinator::request::SubmitError::PromptTooLong { .. }
    ));
    coord.shutdown();
}

#[test]
fn greedy_generation_is_deterministic_across_batching() {
    // the same prompt must generate the same tokens whether it runs alone
    // or next to other requests (row-local quant variant)
    let coord = Arc::new(Coordinator::start(
        tiny_engine(Method::Rtn, Scheme::A4W4KV16),
        SchedulerConfig { max_batch: 4, ..Default::default() },
    ).expect("start coordinator"));
    let solo = coord
        .generate(vec![7, 8, 9], 6, Sampling::Greedy, None)
        .unwrap();
    let mut handles = Vec::new();
    for i in 0..4u32 {
        let c = coord.clone();
        let prompt = if i == 0 { vec![7, 8, 9] } else { vec![40 + i, 50, 60] };
        handles.push(std::thread::spawn(move || {
            (i, c.generate(prompt, 6, Sampling::Greedy, None).unwrap())
        }));
    }
    for h in handles {
        let (i, resp) = h.join().unwrap();
        if i == 0 {
            assert_eq!(resp.tokens, solo.tokens, "batching changed output");
        }
    }
}

#[test]
fn server_protocol_lines() {
    let coord = Coordinator::start(
        tiny_engine(Method::Rrs, Scheme::A4W4KV4),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    let stop = AtomicBool::new(false);
    // generation
    let resp = server::handle_line(
        r#"{"prompt": "arlo", "max_tokens": 4}"#,
        &coord,
        &stop,
    );
    assert!(resp.get("text").is_some(), "{}", resp.dump());
    assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(4));
    // metrics
    let m = server::handle_line(r#"{"cmd": "metrics"}"#, &coord, &stop);
    assert_eq!(m.get("completed").unwrap().as_usize(), Some(1));
    // bad input
    let e = server::handle_line("not json", &coord, &stop);
    assert!(e.get("error").is_some());
    let e2 = server::handle_line(r#"{"max_tokens": 4}"#, &coord, &stop);
    assert!(e2.get("error").is_some());
    // shutdown flips the flag
    let s = server::handle_line(r#"{"cmd": "shutdown"}"#, &coord, &stop);
    assert_eq!(s.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(stop.load(std::sync::atomic::Ordering::Relaxed));
    coord.shutdown();
}

#[test]
fn paged_pool_oversubscribed_completes_with_prefix_sharing() {
    // Pool of 8 blocks x 8 positions = 64 cached positions total, but 12
    // concurrent requests of 24-token prompts + 8 new tokens would need
    // 12 * 4 = 48 blocks held flat.  With two distinct prompts the shared
    // prefixes collapse to a handful of blocks; admission gating +
    // preemption must complete every request without deadlock.
    let model = tiny_model(Method::Rtn, Scheme::A4W4KV4);
    let paged = PagedEngine::new(model, 8, 8);
    let coord = Arc::new(Coordinator::start(
        paged,
        SchedulerConfig { max_batch: 4, queue_capacity: 64, ..Default::default() },
    ).expect("start coordinator"));
    let prompt_a: Vec<u32> = (0..24u32).map(|i| (i * 7 + 3) % 256).collect();
    let prompt_b: Vec<u32> = (0..24u32).map(|i| (i * 11 + 90) % 256).collect();
    let mut handles = Vec::new();
    for i in 0..12u32 {
        let c = coord.clone();
        let prompt = if i % 2 == 0 { prompt_a.clone() } else { prompt_b.clone() };
        handles.push(std::thread::spawn(move || {
            c.generate(prompt, 8, Sampling::Greedy, None).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(
            resp.finish_reason,
            rrs::coordinator::request::FinishReason::MaxTokens
        );
        assert_eq!(resp.tokens.len(), 8);
    }
    assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 12);
    // the acceptance gate: prefix sharing actually happened
    assert!(
        coord.metrics.prefix_hit_tokens.load(Ordering::Relaxed) > 0,
        "prefix cache never hit"
    );
    assert!(coord.metrics.prefix_hit_rate() > 0.0);
}

#[test]
fn paged_pool_exhaustion_preempts_and_recovers() {
    // 7 blocks x 8 positions: two 16-token prompts fit at admission, but
    // both growing to 40 tokens (5 blocks each) cannot coexist — the
    // scheduler must preempt one to the queue and finish it afterwards.
    let model = tiny_model(Method::Rtn, Scheme::A4W4KV4);
    let paged = PagedEngine::new(model, 7, 8);
    let coord = Arc::new(Coordinator::start(
        paged,
        SchedulerConfig { max_batch: 2, queue_capacity: 16, ..Default::default() },
    ).expect("start coordinator"));
    let mut handles = Vec::new();
    for i in 0..2u32 {
        let c = coord.clone();
        // distinct prompts: no prefix sharing can rescue capacity
        let prompt: Vec<u32> = (0..16u32).map(|j| (j * 17 + i * 101 + 1) % 256).collect();
        handles.push(std::thread::spawn(move || {
            c.generate(prompt, 24, Sampling::Greedy, None).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.tokens.len(), 24);
    }
    assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 2);
    assert!(
        coord.metrics.preemptions.load(Ordering::Relaxed) >= 1,
        "pool exhaustion must preempt"
    );
}

#[test]
fn paged_greedy_matches_flat_engine_output() {
    // same model weights, same prompt: the paged coordinator must emit
    // exactly the tokens the flat coordinator emits
    let flat = Coordinator::start(
        tiny_engine(Method::Rtn, Scheme::A4W4KV4),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    let paged = Coordinator::start(
        PagedEngine::new(tiny_model(Method::Rtn, Scheme::A4W4KV4), 32, 8),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    let prompt: Vec<u32> = vec![9, 77, 140, 3, 52];
    let a = flat.generate(prompt.clone(), 10, Sampling::Greedy, None).unwrap();
    let b = paged.generate(prompt, 10, Sampling::Greedy, None).unwrap();
    assert_eq!(a.tokens, b.tokens);
    flat.shutdown();
    paged.shutdown();
}

#[test]
fn backpressure_rejects_when_saturated() {
    // 1-deep queue + tiny batch: flood and expect some rejections
    let coord = Arc::new(Coordinator::start(
        tiny_engine(Method::Rrs, Scheme::A4W4KV4),
        SchedulerConfig {
            max_batch: 1,
            queue_capacity: 1,
            ..Default::default()
        },
    ).expect("start coordinator"));
    let mut rejected = 0;
    let mut receivers = Vec::new();
    for i in 0..16u32 {
        match coord.submit(vec![i + 1, 2, 3], 12, Sampling::Greedy, None) {
            Ok((_, rx)) => receivers.push(rx),
            Err(rrs::coordinator::request::SubmitError::QueueFull) => rejected += 1,
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert!(rejected > 0, "expected backpressure rejections");
    for rx in receivers {
        // accepted ones still complete (drain token frames to the Done)
        rrs::coordinator::request::wait_done(&rx).unwrap();
    }
}
