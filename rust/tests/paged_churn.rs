//! Churn-differential harness: a seeded randomized schedule of
//! admit / decode / preempt / re-admit / retire / cache-pressure ops
//! (≥200 steps) asserting the paged engines stay **bit-identical** to
//! their flat mirrors across arbitrary pool churn — prefix hits,
//! partial-tail adoption, LRU eviction, recompute-preemption and lane
//! residency included.
//!
//! Two backends run the same driver:
//!
//! * the interpreted [`PagedEngine`] with **f32 KV storage**
//!   (`A4W4KV16`), whose rows are exact copies — so any pool bug (wrong
//!   adopted rows, stale blocks, bad tables) breaks bitwise equality
//!   with a flat [`KvCache`] mirror loudly (INT4-KV numerics
//!   equivalence is covered by `kvpool_paged.rs` on matched schedules);
//! * the AOT [`PagedPjrtEngine`] (artifacts-gated), whose pool stores
//!   the graph's own f32 rows verbatim — bitwise against a flat
//!   [`PjrtKvState`] mirror, resident lanes and all.
//!
//! Seed override: `RRS_CHURN_SEED=<n>` (the CI matrix runs 4 seeds).

use rrs::coordinator::engine_iface::ServeEngine;
use rrs::kvpool::{PagedEngine, PagedSeq};
use rrs::model::{EngineConfig, KvCache, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::runtime::{PagedPjrtEngine, PjrtEngine, PjrtKvState};
use rrs::util::rng::Pcg;

// ───────────────────────────── shared driver ─────────────────────────────

/// Flat reference the paged engine is differenced against: one logical
/// sequence, no paging, no prefix cache, no preemption.
trait Mirror {
    /// Reset to a fresh sequence holding `tokens`; returns the last
    /// position's logits.
    fn prefill(&mut self, tokens: &[u32]) -> Vec<f32>;
    /// Append one token; returns its logits.
    fn decode(&mut self, tok: u32) -> Vec<f32>;
}

fn argmax(row: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as u32
}

fn assert_bits(what: &str, paged: &[f32], flat: &[f32]) {
    assert_eq!(paged.len(), flat.len(), "{what}: logit width");
    for (j, (&x, &y)) in paged.iter().zip(flat).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}: logit {j} diverged: paged {x} vs flat {y}"
        );
    }
}

struct Live<M> {
    full_prompt: Vec<u32>,
    generated: Vec<u32>,
    seq: PagedSeq,
    mirror: M,
    last: Vec<f32>,
}

struct Waiting<M> {
    full_prompt: Vec<u32>,
    mirror: M,
    last: Vec<f32>,
}

struct Coverage {
    admits: usize,
    decodes: usize,
    preempts: usize,
    readmits: usize,
    retires: usize,
    refusals: usize,
}

/// A prompt that (usually) shares one of three family prefixes, cut at
/// a random — often mid-block — point, so full-block hits and
/// partial-tail adoption both occur.
fn mk_prompt(rng: &mut Pcg) -> Vec<u32> {
    if rng.below(100) < 60 {
        let fam = rng.below(3) as u32;
        let family: Vec<u32> = (0..14).map(|j| 20 + fam * 60 + j).collect();
        let keep = 4 + rng.below(family.len() - 3);
        let mut p = family[..keep].to_vec();
        let extra = 2 + rng.below(6);
        p.extend((0..extra).map(|_| 200 + rng.next_u32() % 50));
        p
    } else {
        (0..6 + rng.below(10)).map(|_| rng.next_u32() % 250).collect()
    }
}

/// Run `steps` randomized schedule ops over `eng`, differencing every
/// emitted logit row bitwise against per-sequence flat mirrors.
fn churn<E, M, F>(
    eng: &E,
    mut mk_mirror: F,
    seed: u64,
    steps: usize,
    n_slots: usize,
    max_len: usize,
) where
    E: ServeEngine<Seq = PagedSeq>,
    M: Mirror,
    F: FnMut() -> M,
{
    let mut rng = Pcg::new(seed);
    let mut live: Vec<Live<M>> = Vec::new();
    let mut waiting: Vec<Waiting<M>> = Vec::new();
    let mut cov = Coverage {
        admits: 0,
        decodes: 0,
        preempts: 0,
        readmits: 0,
        retires: 0,
        refusals: 0,
    };
    for step in 0..steps {
        match rng.below(10) {
            // ── decode every live sequence (the common op) ──────────────
            0..=4 => {
                if live.is_empty() {
                    continue;
                }
                // preempt anything the pool cannot grow by one token
                let mut i = 0;
                while i < live.len() {
                    if eng.reserve_decode(&mut live[i].seq) {
                        i += 1;
                        continue;
                    }
                    let mut s = live.remove(i);
                    eng.release_seq(&mut s.seq);
                    let mut full = s.full_prompt;
                    full.extend_from_slice(&s.generated);
                    waiting.push(Waiting {
                        full_prompt: full,
                        mirror: s.mirror,
                        last: s.last,
                    });
                    cov.preempts += 1;
                }
                if live.is_empty() {
                    continue;
                }
                let toks: Vec<u32> = live.iter().map(|s| argmax(&s.last)).collect();
                let mut batch: Vec<(&mut PagedSeq, u32)> = live
                    .iter_mut()
                    .zip(&toks)
                    .map(|(s, &t)| (&mut s.seq, t))
                    .collect();
                let logits = eng.decode(&mut batch);
                drop(batch);
                for (i, s) in live.iter_mut().enumerate() {
                    let flat = s.mirror.decode(toks[i]);
                    assert_bits(
                        &format!("step {step} decode slot {i} (seed {seed:#x})"),
                        logits.row(i),
                        &flat,
                    );
                    s.generated.push(toks[i]);
                    s.last = logits.row(i).to_vec();
                }
                cov.decodes += 1;
                // retire anything at its length budget
                let mut i = 0;
                while i < live.len() {
                    let s = &mut live[i];
                    if s.full_prompt.len() + s.generated.len() + 2 >= max_len {
                        eng.release_seq(&mut s.seq);
                        live.remove(i);
                        cov.retires += 1;
                    } else {
                        i += 1;
                    }
                }
            }
            // ── admit: waiting (re-admission) first, then a fresh prompt ─
            5 | 6 => {
                if live.len() >= n_slots {
                    continue;
                }
                if let Some(w) = waiting.pop() {
                    if !eng.can_admit(&w.full_prompt) {
                        cov.refusals += 1;
                        waiting.push(w);
                        continue;
                    }
                    let mut seq = eng.new_seq();
                    match eng.try_prefill(&mut seq, &w.full_prompt) {
                        Some(lg) => {
                            // recompute-preemption must land exactly where
                            // the sequence left off
                            assert_bits(
                                &format!("step {step} re-admit (seed {seed:#x})"),
                                &lg,
                                &w.last,
                            );
                            live.push(Live {
                                full_prompt: w.full_prompt,
                                generated: Vec::new(),
                                seq,
                                mirror: w.mirror,
                                last: lg,
                            });
                            cov.readmits += 1;
                        }
                        None => {
                            cov.refusals += 1;
                            waiting.push(w);
                        }
                    }
                } else {
                    let prompt = mk_prompt(&mut rng);
                    if prompt.len() + 16 >= max_len || !eng.can_admit(&prompt) {
                        cov.refusals += 1;
                        continue;
                    }
                    let mut seq = eng.new_seq();
                    let Some(lg) = eng.try_prefill(&mut seq, &prompt) else {
                        cov.refusals += 1;
                        continue;
                    };
                    let mut mirror = mk_mirror();
                    let flat = mirror.prefill(&prompt);
                    assert_bits(
                        &format!("step {step} admit (seed {seed:#x})"),
                        &lg,
                        &flat,
                    );
                    live.push(Live {
                        full_prompt: prompt,
                        generated: Vec::new(),
                        seq,
                        mirror,
                        last: lg,
                    });
                    cov.admits += 1;
                }
            }
            // ── preempt a random live sequence (recompute-style) ────────
            7 => {
                if live.is_empty() {
                    continue;
                }
                let i = rng.below(live.len());
                let mut s = live.remove(i);
                eng.release_seq(&mut s.seq);
                let mut full = s.full_prompt;
                full.extend_from_slice(&s.generated);
                waiting.push(Waiting {
                    full_prompt: full,
                    mirror: s.mirror,
                    last: s.last,
                });
                cov.preempts += 1;
            }
            // ── retire a random live sequence ───────────────────────────
            8 => {
                if live.is_empty() {
                    continue;
                }
                let i = rng.below(live.len());
                let mut s = live.remove(i);
                eng.release_seq(&mut s.seq);
                cov.retires += 1;
            }
            // ── cache pressure: throwaway prefill + release (seals
            //    foreign chains, drains the free list, forces LRU) ───────
            _ => {
                let prompt: Vec<u32> =
                    (0..12 + rng.below(8)).map(|_| rng.next_u32() % 250).collect();
                if !eng.can_admit(&prompt) {
                    cov.refusals += 1;
                    continue;
                }
                let mut seq = eng.new_seq();
                if eng.try_prefill(&mut seq, &prompt).is_some() {
                    eng.release_seq(&mut seq);
                }
            }
        }
    }
    for mut s in live {
        eng.release_seq(&mut s.seq);
    }
    eprintln!(
        "churn seed {seed:#x}: {} admits, {} decodes, {} preempts, \
         {} readmits, {} retires, {} refusals",
        cov.admits, cov.decodes, cov.preempts, cov.readmits, cov.retires, cov.refusals
    );
    assert!(cov.admits >= 1, "schedule never admitted (seed {seed:#x})");
    assert!(cov.decodes >= 1, "schedule never decoded (seed {seed:#x})");
    assert!(cov.preempts >= 1, "schedule never preempted (seed {seed:#x})");
    assert!(cov.readmits >= 1, "schedule never re-admitted (seed {seed:#x})");
}

fn churn_seeds() -> Vec<u64> {
    match std::env::var("RRS_CHURN_SEED") {
        Ok(s) => vec![s.trim().parse().expect("RRS_CHURN_SEED must be a u64")],
        Err(_) => vec![0xC0FFEE],
    }
}

// ─────────────────────────── interpreted backend ──────────────────────────

fn churn_model(seed: u64) -> (QuantModel, ModelConfig, EngineConfig) {
    let cfg = ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() };
    let w = Weights::random(&cfg, seed);
    // f32 KV storage (A4W4KV16): pool rows are exact copies, so paged
    // serving must be *bitwise* flat — the strictest differential
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV16,
        group: 32,
        kv_group: 32,
        gptq: false,
        ..Default::default()
    };
    let m = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
    (m, cfg, ecfg)
}

struct InterpMirror {
    /// Shared prepared model: one quantization pass, many mirrors.
    model: std::rc::Rc<QuantModel>,
    cfg: ModelConfig,
    ecfg: EngineConfig,
    cache: KvCache,
}

impl Mirror for InterpMirror {
    fn prefill(&mut self, tokens: &[u32]) -> Vec<f32> {
        self.cache = KvCache::new(&self.cfg, &self.ecfg);
        let lg = self.model.forward_full(tokens, Some(&mut self.cache));
        lg.row(lg.rows - 1).to_vec()
    }

    fn decode(&mut self, tok: u32) -> Vec<f32> {
        let mut batch = [(&mut self.cache, tok)];
        let lg = self.model.decode_batch(&mut batch);
        lg.row(0).to_vec()
    }
}

#[test]
fn interpreted_churn_bit_identical_to_flat() {
    let (model, ..) = churn_model(7);
    // 40 blocks x 4 positions: tight enough that preemption, eviction
    // and admission refusals all fire under the schedule
    let eng = PagedEngine::new(model, 40, 4);
    let (mirror_model, cfg, ecfg) = churn_model(7);
    let mirror_model = std::rc::Rc::new(mirror_model);
    for seed in churn_seeds() {
        churn(
            &eng,
            || InterpMirror {
                model: mirror_model.clone(),
                cfg,
                ecfg,
                cache: KvCache::new(&cfg, &ecfg),
            },
            seed,
            220,
            5,
            56,
        );
    }
    let s = eng.stats();
    eprintln!(
        "pool after churn: {} evictions, {} partial hits, {} cow copies, \
         {} hit tokens",
        s.evictions, s.prefix_partial_hits, s.cow_copies, s.prefix_hit_tokens
    );
    assert!(s.prefix_hit_tokens > 0, "churn never hit the prefix cache");
}

// ────────────────────────────── PJRT backend ──────────────────────────────

fn artifacts_root() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn have_artifacts() -> bool {
    std::path::Path::new(artifacts_root()).join("manifest.json").exists()
}

macro_rules! need_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
    };
}

struct PjrtMirror {
    /// Shared flat runtime: one compile, many mirror sequences.
    rt: std::rc::Rc<PjrtEngine>,
    state: PjrtKvState,
    vocab: usize,
    lanes: usize,
}

impl PjrtMirror {
    fn new(rt: std::rc::Rc<PjrtEngine>) -> PjrtMirror {
        let state = rt.new_kv_state();
        let vocab = rt.artifacts.model.vocab;
        let lanes = rt.artifacts.decode_batch;
        PjrtMirror { rt, state, vocab, lanes }
    }
}

impl Mirror for PjrtMirror {
    fn prefill(&mut self, tokens: &[u32]) -> Vec<f32> {
        self.state = self.rt.new_kv_state();
        let mut last = Vec::new();
        for &t in tokens {
            let lg = self
                .rt
                .decode_step("fp", &vec![t as i32; self.lanes], &mut self.state)
                .unwrap();
            last = lg[..self.vocab].to_vec();
        }
        last
    }

    fn decode(&mut self, tok: u32) -> Vec<f32> {
        let lg = self
            .rt
            .decode_step("fp", &vec![tok as i32; self.lanes], &mut self.state)
            .unwrap();
        lg[..self.vocab].to_vec()
    }
}

#[test]
fn pjrt_churn_bit_identical_to_flat() {
    need_artifacts!();
    let eng = PagedPjrtEngine::new(artifacts_root(), "fp", 48, 4).unwrap();
    let rt = std::rc::Rc::new(PjrtEngine::new(artifacts_root()).unwrap());
    for seed in churn_seeds() {
        churn(&eng, || PjrtMirror::new(rt.clone()), seed, 200, 5, 48);
    }
    let rs = eng.residency_stats();
    eprintln!(
        "residency after churn: {} gathers, {} refreshes, {} scatter rows, \
         {} hits, {} graph calls",
        rs.kv_gather_total,
        rs.lane_refresh_total,
        rs.kv_scatter_rows_total,
        rs.resident_hits,
        rs.decode_graph_calls
    );
    if eng.residency_enabled() && rs.decode_graph_calls > 50 {
        assert!(rs.resident_hits > 0, "resident fast path never hit");
    }
}

/// The acceptance gate for per-lane positions: sequences parked at
/// positions {3, 17, 64} decode in ONE graph call, each lane bit-equal
/// to its own flat single-sequence decode.
#[test]
fn unequal_positions_decode_in_one_graph_call() {
    need_artifacts!();
    let eng = PagedPjrtEngine::new(artifacts_root(), "fp", 96, 4).unwrap();
    if !eng.per_lane_pos() {
        eprintln!("skipping: legacy scalar-position artifacts");
        return;
    }
    let lens = [3usize, 17, 64];
    let prompts: Vec<Vec<u32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| (0..n as u32).map(|j| 30 + i as u32 * 40 + j % 90).collect())
        .collect();

    let mut seqs: Vec<PagedSeq> = Vec::new();
    let mut mirrors: Vec<PjrtMirror> = Vec::new();
    let mut lasts: Vec<Vec<f32>> = Vec::new();
    let rt = std::rc::Rc::new(PjrtEngine::new(artifacts_root()).unwrap());
    for p in &prompts {
        let mut seq = eng.new_seq();
        let lg = eng.try_prefill(&mut seq, p).unwrap().unwrap();
        let mut m = PjrtMirror::new(rt.clone());
        let flat = m.prefill(p);
        assert_bits("unequal prefill", &lg, &flat);
        seqs.push(seq);
        mirrors.push(m);
        lasts.push(lg);
    }
    for (i, &n) in lens.iter().enumerate() {
        assert_eq!(seqs[i].len, n, "prompt {i} cached length");
    }

    for step in 0..4 {
        let toks: Vec<u32> = lasts.iter().map(|l| argmax(l)).collect();
        let before = eng.residency_stats();
        let mut batch: Vec<(&mut PagedSeq, u32)> =
            seqs.iter_mut().zip(&toks).map(|(s, &t)| (s, t)).collect();
        let logits = eng.decode(&mut batch).unwrap();
        drop(batch);
        let after = eng.residency_stats();
        assert_eq!(
            after.decode_graph_calls - before.decode_graph_calls,
            1,
            "step {step}: 3 lanes at unequal positions must share ONE call"
        );
        if step > 0 {
            assert_eq!(
                after.kv_gather_total, before.kv_gather_total,
                "step {step}: steady-state decode re-gathered"
            );
        }
        for i in 0..seqs.len() {
            let flat = mirrors[i].decode(toks[i]);
            assert_bits(&format!("step {step} lane {i}"), logits.row(i), &flat);
            lasts[i] = logits.row(i).to_vec();
        }
    }
    for s in seqs.iter_mut() {
        eng.release(s);
    }
}

/// The O(1) acceptance gate: once lanes are resident, decode performs
/// ZERO full-cache gathers — `kv_gather_total` goes flat while the
/// scatter counter keeps advancing one row set per token.
#[test]
fn steady_state_decode_performs_zero_full_cache_gathers() {
    need_artifacts!();
    let eng = PagedPjrtEngine::new(artifacts_root(), "fp", 96, 4).unwrap();
    if !eng.residency_enabled() {
        eprintln!("skipping: residency unavailable (legacy artifacts)");
        return;
    }
    let prompts: Vec<Vec<u32>> = (0..3u32)
        .map(|i| (0..8u32).map(|j| 40 + i * 30 + j).collect())
        .collect();
    let mut seqs: Vec<PagedSeq> = prompts
        .iter()
        .map(|p| {
            let mut s = eng.new_seq();
            eng.try_prefill(&mut s, p).unwrap().unwrap();
            s
        })
        .collect();
    let mut decode_once = |seqs: &mut Vec<PagedSeq>| {
        let mut batch: Vec<(&mut PagedSeq, u32)> =
            seqs.iter_mut().map(|s| (s, 50u32)).collect();
        eng.decode(&mut batch).unwrap();
    };
    // first decode refreshes the lanes (admission -> resident)
    decode_once(&mut seqs);
    let warm = eng.residency_stats();
    assert!(warm.lane_refresh_total >= 3, "admission must refresh lanes");
    // one steady step calibrates the per-step scatter volume
    // (seqs x n_layers rows) without hardcoding the layer count
    decode_once(&mut seqs);
    let cal = eng.residency_stats();
    let rows_per_step = cal.kv_scatter_rows_total - warm.kv_scatter_rows_total;
    assert!(rows_per_step > 0 && rows_per_step % 3 == 0);
    assert_eq!(cal.kv_gather_total, warm.kv_gather_total);
    for step in 0..10 {
        decode_once(&mut seqs);
        let s = eng.residency_stats();
        assert_eq!(
            s.kv_gather_total, warm.kv_gather_total,
            "step {step}: steady-state decode performed a full-cache gather"
        );
    }
    let done = eng.residency_stats();
    assert_eq!(
        done.kv_scatter_rows_total - cal.kv_scatter_rows_total,
        10 * rows_per_step,
        "each decoded token scatters exactly one row per layer"
    );
    for s in seqs.iter_mut() {
        eng.release(s);
    }
}
