//! Sampler suite locks: seeded property tests over the per-request
//! distribution ([`SamplerState::distribution`]) — nucleus mass
//! invariant, temp→0 ≡ greedy, repetition-penalty monotonicity,
//! logit-bias ban exclusion, top-k support — plus engine-level seeded
//! determinism: the same seeded request produces the same token stream
//! whether it runs solo, batched, flat, paged, or preempted-and-resumed
//! mid-stream.  The PJRT variant is artifacts-gated (skips cleanly).

use std::sync::Arc;

use rrs::coordinator::{
    Coordinator, RequestOptions, RustServeEngine, SamplerState, SamplingParams,
    SchedulerConfig,
};
use rrs::kvpool::PagedEngine;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::util::proptest::{check, Config};
use rrs::util::rng::Pcg;

const V: usize = 64;

fn rand_logits(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * 2.0).collect()
}

fn rand_token(rng: &mut Pcg, n: usize) -> u32 {
    ((rng.uniform() * n as f32) as usize).min(n - 1) as u32
}

/// Reference softmax over `logits / temp` (NaN treated as banned).
fn ref_softmax(logits: &[f32], temp: f32) -> Vec<f32> {
    let scaled: Vec<f32> = logits
        .iter()
        .map(|&l| if l.is_nan() { f32::NEG_INFINITY } else { l / temp })
        .collect();
    let m = scaled.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = scaled.iter().map(|&l| (l - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / z).collect()
}

fn ref_argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        if !l.is_nan() && l > best_v {
            best = i;
            best_v = l;
        }
    }
    best as u32
}

// ---------------------------------------------------------------- properties

#[test]
fn prop_zero_temperature_is_greedy() {
    check("temp0-greedy", Config::default(), |rng, case| {
        let logits = rand_logits(rng, V);
        // temp 0 must collapse to argmax no matter what the other knobs
        // or the seed say
        let p = SamplingParams {
            temperature: 0.0,
            top_k: 1 + case % 16,
            top_p: 0.25 + 0.75 * rng.uniform(),
            seed: Some(case as u64),
            ..Default::default()
        };
        let mut st = SamplerState::new(p, case as u64, &[]);
        let d = st.distribution(&logits);
        if d.len() != 1 {
            return Err(format!("greedy support {} != 1", d.len()));
        }
        if d[0].0 != ref_argmax(&logits) {
            return Err(format!("greedy picked {} not argmax", d[0].0));
        }
        let t = st.sample(&logits);
        if t != ref_argmax(&logits) {
            return Err(format!("sample {t} != argmax"));
        }
        Ok(())
    });
}

#[test]
fn prop_nucleus_mass_invariant() {
    // the kept set is the smallest probability-descending prefix with
    // mass >= top_p, and the returned probabilities renormalize to 1
    check("nucleus-mass", Config { cases: 128, ..Default::default() }, |rng, _| {
        let logits = rand_logits(rng, V);
        let temp = 0.25 + 1.75 * rng.uniform();
        let top_p = (rng.uniform() * 0.98 + 0.01).min(1.0);
        let p = SamplingParams { temperature: temp, top_p, ..Default::default() };
        let st = SamplerState::new(p, 1, &[]);
        let d = st.distribution(&logits);
        let sum: f32 = d.iter().map(|c| c.1).sum();
        if (sum - 1.0).abs() > 1e-3 {
            return Err(format!("renormalized mass {sum} != 1"));
        }
        for w in d.windows(2) {
            if w[1].1 > w[0].1 + 1e-6 {
                return Err("nucleus candidates not probability-descending".into());
            }
        }
        let pref = ref_softmax(&logits, temp);
        let kept_mass: f32 = d.iter().map(|&(t, _)| pref[t as usize]).sum();
        if kept_mass < top_p - 1e-4 {
            return Err(format!("kept mass {kept_mass} < top_p {top_p}"));
        }
        // minimality: dropping the least-probable kept candidate must
        // fall below top_p (otherwise the nucleus was not smallest)
        if d.len() > 1 {
            let smallest = d
                .iter()
                .map(|&(t, _)| pref[t as usize])
                .fold(f32::INFINITY, f32::min);
            if kept_mass - smallest >= top_p + 1e-4 {
                return Err(format!(
                    "nucleus not minimal: {} candidates, mass {kept_mass}, \
                     smallest {smallest}, top_p {top_p}",
                    d.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_top_k_support_is_the_k_largest() {
    check("topk-support", Config::default(), |rng, case| {
        let mut logits = rand_logits(rng, V);
        // NaN logits are banned, never sampled, never in the support
        logits[case % V] = f32::NAN;
        let k = 1 + case % 16;
        let p = SamplingParams { temperature: 1.0, top_k: k, ..Default::default() };
        let st = SamplerState::new(p, 1, &[]);
        let d = st.distribution(&logits);
        if d.len() > k {
            return Err(format!("support {} > k {k}", d.len()));
        }
        let kept: Vec<u32> = d.iter().map(|c| c.0).collect();
        if kept.iter().any(|&t| logits[t as usize].is_nan()) {
            return Err("NaN logit in support".into());
        }
        let min_kept = kept
            .iter()
            .map(|&t| logits[t as usize])
            .fold(f32::INFINITY, f32::min);
        let max_dropped = (0..V)
            .filter(|i| !kept.contains(&(*i as u32)) && !logits[*i].is_nan())
            .map(|i| logits[i])
            .fold(f32::NEG_INFINITY, f32::max);
        if d.len() == k && max_dropped > min_kept {
            return Err(format!(
                "dropped logit {max_dropped} above kept {min_kept}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_repetition_penalty_is_monotone() {
    // a token already in the history can only get less probable as the
    // penalty grows (positive logits divided, negative multiplied)
    check("rep-penalty-monotone", Config::default(), |rng, _| {
        let logits = rand_logits(rng, V);
        let h = rand_token(rng, V);
        let r1 = 1.0 + rng.uniform();
        let r2 = r1 + 0.25 + rng.uniform();
        let prob_of = |r: f32| -> f32 {
            let p = SamplingParams {
                temperature: 1.0,
                repetition_penalty: r,
                ..Default::default()
            };
            let st = SamplerState::new(p, 1, &[h]);
            st.distribution(&logits)
                .iter()
                .find(|&&(t, _)| t == h)
                .map(|c| c.1)
                .unwrap_or(0.0)
        };
        let (p0, p1, p2) = (prob_of(1.0), prob_of(r1), prob_of(r2));
        if p1 > p0 + 1e-6 || p2 > p1 + 1e-6 {
            return Err(format!(
                "penalty not monotone for token {h}: {p0} -> {p1} (r {r1}) \
                 -> {p2} (r {r2})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_banned_tokens_never_sampled() {
    check("ban-exclusion", Config::default(), |rng, case| {
        let mut logits = rand_logits(rng, V);
        let banned: Vec<u32> = (0..6).map(|_| rand_token(rng, V)).collect();
        // make a banned token the argmax so exclusion is load-bearing
        logits[banned[0] as usize] = 50.0;
        let p = SamplingParams {
            temperature: 0.1 + 1.4 * rng.uniform(),
            top_k: (case % 2) * 12, // alternate top-k off / 12
            logit_bias: banned
                .iter()
                .map(|&t| (t, rrs::coordinator::sampling::BAN_BIAS))
                .collect(),
            seed: Some(case as u64),
            ..Default::default()
        };
        let mut st = SamplerState::new(p, 1, &[]);
        if st.distribution(&logits).iter().any(|&(t, _)| banned.contains(&t)) {
            return Err("banned token in distribution support".into());
        }
        for _ in 0..20 {
            let t = st.sample(&logits);
            if banned.contains(&t) {
                return Err(format!("sampled banned token {t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_seeded_replay_is_exact() {
    // the stream is a pure function of (logits, params, seed): replaying
    // with a different request id and batch position changes nothing
    check("seeded-replay", Config { cases: 32, ..Default::default() }, |rng, case| {
        let p = SamplingParams {
            temperature: 0.5 + rng.uniform(),
            top_k: 8 + case % 24,
            top_p: 0.8 + 0.2 * rng.uniform(),
            repetition_penalty: 1.1,
            seed: Some(0xabc0 + case as u64),
            ..Default::default()
        };
        let mut a = SamplerState::new(p.clone(), 7, &[1, 2]);
        let mut b = SamplerState::new(p, 99_999, &[1, 2]);
        for step in 0..24 {
            let logits = rand_logits(rng, V);
            let (x, y) = (a.sample(&logits), b.sample(&logits));
            if x != y {
                return Err(format!("step {step}: {x} != {y}"));
            }
        }
        Ok(())
    });
}

// ------------------------------------------------- engine-level determinism

fn tiny_model(method: Method, scheme: Scheme) -> QuantModel {
    let cfg = ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() };
    let w = Weights::random(&cfg, 42);
    let calib: Vec<u32> = (0..128u32).map(|i| (i * 53 + 7) % 256).collect();
    let ecfg = EngineConfig {
        method,
        scheme,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    QuantModel::prepare(&w, &cfg, &ecfg, Some(&calib), None).unwrap()
}

fn seeded_opts(seed: u64, max_new_tokens: usize) -> RequestOptions {
    RequestOptions {
        max_new_tokens,
        params: SamplingParams {
            temperature: 0.9,
            top_k: 20,
            top_p: 0.95,
            repetition_penalty: 1.1,
            seed: Some(seed),
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn seeded_stream_identical_flat_vs_paged() {
    let flat = Coordinator::start(
        RustServeEngine::new(tiny_model(Method::Rtn, Scheme::A4W4KV4)),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    let paged = Coordinator::start(
        PagedEngine::new(tiny_model(Method::Rtn, Scheme::A4W4KV4), 32, 8),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    let prompt: Vec<u32> = vec![9, 77, 140, 3, 52];
    let a = flat.generate_opts(prompt.clone(), seeded_opts(1234, 12)).unwrap();
    let a2 = flat.generate_opts(prompt.clone(), seeded_opts(1234, 12)).unwrap();
    let b = paged.generate_opts(prompt, seeded_opts(1234, 12)).unwrap();
    assert_eq!(a.tokens, a2.tokens, "flat replay diverged");
    assert_eq!(a.tokens, b.tokens, "paged engine diverged from flat");
    flat.shutdown();
    paged.shutdown();
}

#[test]
fn seeded_stream_identical_solo_vs_batched() {
    // same prompt + seed must sample the same stream whether it runs
    // alone or interleaved with other sampled requests (row-local quant
    // variant, and every lane owns a private RNG stream)
    let coord = Arc::new(Coordinator::start(
        RustServeEngine::new(tiny_model(Method::Rtn, Scheme::A4W4KV16)),
        SchedulerConfig { max_batch: 4, ..Default::default() },
    ).expect("start coordinator"));
    let solo = coord
        .generate_opts(vec![7, 8, 9], seeded_opts(777, 10))
        .unwrap();
    let mut handles = Vec::new();
    for i in 0..4u32 {
        let c = coord.clone();
        let (prompt, seed) = if i == 0 {
            (vec![7, 8, 9], 777)
        } else {
            (vec![40 + i, 50, 60], 1000 + i as u64)
        };
        handles.push(std::thread::spawn(move || {
            (i, c.generate_opts(prompt, seeded_opts(seed, 10)).unwrap())
        }));
    }
    for h in handles {
        let (i, resp) = h.join().unwrap();
        if i == 0 {
            assert_eq!(resp.tokens, solo.tokens, "batching changed the stream");
        }
    }
}

#[test]
fn seeded_stream_survives_preemption() {
    // a 7-block pool cannot hold both growing sequences: one is
    // preempted (blocks released) and re-prefilled later.  The preserved
    // SamplerState + bit-identical re-prefill must continue the exact
    // stream an unpressured pool produces.
    let reference = Coordinator::start(
        PagedEngine::new(tiny_model(Method::Rtn, Scheme::A4W4KV4), 32, 8),
        SchedulerConfig::default(),
    ).expect("start coordinator");
    let prompts: Vec<Vec<u32>> = (0..2u32)
        .map(|i| (0..16u32).map(|j| (j * 17 + i * 101 + 1) % 256).collect())
        .collect();
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            reference
                .generate_opts(p.clone(), seeded_opts(11 + i as u64, 24))
                .unwrap()
                .tokens
        })
        .collect();
    reference.shutdown();

    let coord = Arc::new(Coordinator::start(
        PagedEngine::new(tiny_model(Method::Rtn, Scheme::A4W4KV4), 7, 8),
        SchedulerConfig { max_batch: 2, queue_capacity: 16, ..Default::default() },
    ).expect("start coordinator"));
    let mut handles = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let c = coord.clone();
        let p = p.clone();
        handles.push(std::thread::spawn(move || {
            (i, c.generate_opts(p, seeded_opts(11 + i as u64, 24)).unwrap())
        }));
    }
    for h in handles {
        let (i, resp) = h.join().unwrap();
        assert_eq!(resp.tokens.len(), 24);
        assert_eq!(resp.tokens, want[i], "preemption changed request {i}'s stream");
    }
    assert!(
        coord
            .metrics
            .preemptions
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 1,
        "pool never preempted: the property was not exercised"
    );
}

// ----------------------------------------------------- PJRT (artifacts-gated)

fn artifacts_root() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn have_artifacts() -> bool {
    std::path::Path::new(artifacts_root()).join("manifest.json").exists()
}

#[test]
fn pjrt_paged_seeded_stream_replays() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    }
    use rrs::runtime::PagedPjrtEngine;
    let prompt: Vec<u32> = vec![97, 114, 108, 111, 32, 105, 115];
    let run = || {
        let engine = PagedPjrtEngine::new(artifacts_root(), "fp", 64, 4).unwrap();
        let coord = Coordinator::start(
            engine,
            SchedulerConfig { max_batch: 2, ..Default::default() },
        ).expect("start coordinator");
        let resp = coord
            .generate_opts(prompt.clone(), seeded_opts(4242, 8))
            .unwrap();
        coord.shutdown();
        resp.tokens
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), 8);
    assert_eq!(a, b, "PJRT paged backend seeded stream diverged");
}
