//! Method-level end-to-end invariants on a random model + property tests
//! over the scheduler-facing engine behaviours.  No artifacts required.

use rrs::model::{EngineConfig, KvCache, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::util::proptest::{check, Config};

fn cfg() -> ModelConfig {
    ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() }
}

fn calib() -> Vec<u32> {
    (0..128u32).map(|i| (i * 53 + 7) % 256).collect()
}

#[test]
fn spinquant_method_runs_with_dense_rotations() {
    use rrs::linalg::fwht::hadamard_dense;
    use rrs::linalg::gemm::Mat;
    let c = cfg();
    let w = Weights::random(&c, 1);
    // any orthogonal matrices work; reuse dense Hadamards as stand-ins
    let rd = Mat::from_vec(c.dim, c.dim, hadamard_dense(c.dim));
    let rf = Mat::from_vec(c.ffn, c.ffn, hadamard_dense(c.ffn));
    let ecfg = EngineConfig {
        method: Method::SpinQuant,
        scheme: Scheme::A4W4KV4,
        group: 32,
        gptq: true,
        ..Default::default()
    };
    let calib = calib();
    let m = QuantModel::prepare(&w, &c, &ecfg, Some(&calib), Some((rd, rf))).unwrap();
    let lg = m.forward_full(&[1, 2, 3, 4], None);
    assert!(lg.data.iter().all(|v| v.is_finite()));
}

#[test]
fn spinquant_requires_rotations() {
    let c = cfg();
    let w = Weights::random(&c, 2);
    let ecfg = EngineConfig {
        method: Method::SpinQuant,
        scheme: Scheme::A4W4KV4,
        gptq: false,
        ..Default::default()
    };
    assert!(QuantModel::prepare(&w, &c, &ecfg, Some(&calib()), None).is_err());
}

#[test]
fn gptq_weights_no_worse_than_rtn_weights_e2e() {
    // property: GPTQ vs RTN weights under the same rtn activations —
    // logit error vs fp should not be (much) worse with GPTQ
    let c = cfg();
    let w = Weights::random(&c, 3);
    let toks: Vec<u32> = (0..32u32).map(|i| (i * 37 + 3) % 256).collect();
    let fp = {
        let ecfg = EngineConfig {
            method: Method::Fp,
            scheme: Scheme::FP,
            gptq: false,
            ..Default::default()
        };
        QuantModel::prepare(&w, &c, &ecfg, None, None)
            .unwrap()
            .forward_full(&toks, None)
    };
    let err_of = |gptq: bool| {
        let ecfg = EngineConfig {
            method: if gptq { Method::GptqOnly } else { Method::Rtn },
            scheme: Scheme::A4W4KV16,
            gptq,
            ..Default::default()
        };
        let calib = calib();
        let m = QuantModel::prepare(&w, &c, &ecfg, Some(&calib), None).unwrap();
        let lg = m.forward_full(&toks, None);
        lg.data
            .iter()
            .zip(&fp.data)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / lg.data.len() as f32
    };
    let e_rtn = err_of(false);
    let e_gptq = err_of(true);
    assert!(e_gptq < e_rtn * 1.5, "gptq {e_gptq} vs rtn {e_rtn}");
}

#[test]
fn decode_batch_order_invariance() {
    // property: each sequence's decode result does not depend on its
    // position within the batch (row-local variant)
    let c = cfg();
    let w = Weights::random(&c, 4);
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV16,
        gptq: false,
        ..Default::default()
    };
    let m = QuantModel::prepare(&w, &c, &ecfg, None, None).unwrap();
    check("decode-order-invariance", Config { cases: 8, ..Default::default() },
        |rng, _| {
            let t1 = rng.below(256) as u32;
            let t2 = rng.below(256) as u32;
            // order (a, b)
            let mut ca = KvCache::new(&c, &ecfg);
            let mut cb = KvCache::new(&c, &ecfg);
            let mut batch = [(&mut ca, t1), (&mut cb, t2)];
            let l_ab = m.decode_batch(&mut batch);
            // order (b, a)
            let mut ca2 = KvCache::new(&c, &ecfg);
            let mut cb2 = KvCache::new(&c, &ecfg);
            let mut batch2 = [(&mut cb2, t2), (&mut ca2, t1)];
            let l_ba = m.decode_batch(&mut batch2);
            for (x, y) in l_ab.row(0).iter().zip(l_ba.row(1)) {
                if (x - y).abs() > 1e-4 {
                    return Err(format!("row for t1 differs: {x} vs {y}"));
                }
            }
            Ok(())
        });
}

#[test]
fn kv4_quality_close_to_kv16() {
    let c = cfg();
    let w = Weights::random(&c, 5);
    let toks: Vec<u32> = (0..48u32).map(|i| (i * 29 + 1) % 256).collect();
    let run = |kv: Scheme| {
        let ecfg = EngineConfig {
            method: Method::Rrs,
            scheme: kv,
            group: 32,
            gptq: false,
            ..Default::default()
        };
        let m = QuantModel::prepare(&w, &c, &ecfg, None, None).unwrap();
        m.forward_full(&toks, None)
    };
    let a = run(Scheme::A4W4KV16);
    let b = run(Scheme::A4W4KV4);
    let corr = {
        let n = a.data.len() as f32;
        let ma = a.data.iter().sum::<f32>() / n;
        let mb = b.data.iter().sum::<f32>() / n;
        let (mut num, mut da, mut db) = (0.0, 0.0, 0.0);
        for (&x, &y) in a.data.iter().zip(&b.data) {
            num += (x - ma) * (y - mb);
            da += (x - ma) * (x - ma);
            db += (y - mb) * (y - mb);
        }
        num / (da.sqrt() * db.sqrt() + 1e-12)
    };
    assert!(corr > 0.85, "kv4-vs-kv16 corr {corr}");
}

#[test]
fn group_size_changes_rs_but_not_much_rrs_on_spiky() {
    // Table-4 mechanism at engine level: with spiky activations, RS
    // quality depends on group size more than RRS does
    let c = cfg();
    let w = Weights::random(&c, 6);
    let prof = rrs::model::weights::OutlierProfile::builtin("llama3-70b-like").unwrap();
    let wi = prof.inject(&w, 17);
    let toks: Vec<u32> = (0..64u32).map(|i| (i * 41 + 9) % 256).collect();
    let fp = {
        let ecfg = EngineConfig {
            method: Method::Fp,
            scheme: Scheme::FP,
            gptq: false,
            ..Default::default()
        };
        QuantModel::prepare(&wi, &c, &ecfg, None, None)
            .unwrap()
            .forward_full(&toks, None)
    };
    let err_of = |method: Method, group: usize| {
        let ecfg = EngineConfig {
            method,
            scheme: Scheme::A4W16KV16,
            group,
            gptq: false,
            ..Default::default()
        };
        let m = QuantModel::prepare(&wi, &c, &ecfg, None, None).unwrap();
        let lg = m.forward_full(&toks, None);
        lg.data
            .iter()
            .zip(&fp.data)
            .map(|(x, y)| (x - y).abs())
            .sum::<f32>()
    };
    let rs_spread = err_of(Method::Rs, 128) / err_of(Method::Rs, 1).max(1e-6);
    let rrs_spread = err_of(Method::Rrs, 128) / err_of(Method::Rrs, 1).max(1e-6);
    assert!(
        rrs_spread < rs_spread * 1.2,
        "rrs group-sensitivity {rrs_spread} vs rs {rs_spread}"
    );
}
