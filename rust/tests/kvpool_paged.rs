//! Paged KV-pool engine vs the flat-cache path: bit-identity of the
//! block-table attention, prefix-cache reuse quality, and block-table
//! roundtrip against the flat store.  Uses small random models only.

use rrs::kvpool::{KvPool, KvPoolConfig, PagedEngine};
use rrs::model::engine::KvStore;
use rrs::model::{EngineConfig, KvCache, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::util::rng::Pcg;

fn tiny_model(seed: u64) -> (QuantModel, ModelConfig, EngineConfig) {
    let cfg = ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() };
    let w = Weights::random(&cfg, seed);
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV4,
        group: 32,
        kv_group: 32,
        gptq: false,
        ..Default::default()
    };
    let m = QuantModel::prepare(&w, &cfg, &ecfg, None, None).unwrap();
    (m, cfg, ecfg)
}

/// The acceptance gate: the same seeded prompt through the flat cache and
/// through the block-table pool must produce *bit-identical* logits at
/// prefill and every decode step (same quantized rows, same op order).
#[test]
fn paged_attention_bit_identical_to_flat_cache() {
    let (model, cfg, ecfg) = tiny_model(7);
    let prompt: Vec<u32> = vec![5, 9, 200, 31, 77, 3, 18, 42, 99, 120];
    let steps = 12usize;

    // flat path
    let mut flat_cache = KvCache::new(&cfg, &ecfg);
    let flat_prefill = model.forward_full(&prompt, Some(&mut flat_cache));
    let mut flat_logits: Vec<Vec<f32>> =
        vec![flat_prefill.row(flat_prefill.rows - 1).to_vec()];
    let mut flat_tokens = Vec::new();
    for _ in 0..steps {
        let tok = argmax_u32(flat_logits.last().unwrap());
        flat_tokens.push(tok);
        let mut batch = [(&mut flat_cache, tok)];
        let lg = model.decode_batch(&mut batch);
        flat_logits.push(lg.row(0).to_vec());
    }

    // paged path (block size 4 => the prompt spans multiple blocks)
    let (model2, ..) = tiny_model(7);
    let paged = PagedEngine::new(model2, 64, 4);
    let mut seq = paged.new_seq();
    let mut paged_logits: Vec<Vec<f32>> =
        vec![paged.try_prefill(&mut seq, &prompt).expect("prefill")];
    let mut paged_tokens = Vec::new();
    for _ in 0..steps {
        let tok = argmax_u32(paged_logits.last().unwrap());
        paged_tokens.push(tok);
        let mut batch = [(&mut seq, tok)];
        let lg = paged.decode(&mut batch);
        paged_logits.push(lg.row(0).to_vec());
    }

    assert_eq!(flat_tokens, paged_tokens, "greedy tokens diverged");
    for (step, (a, b)) in flat_logits.iter().zip(&paged_logits).enumerate() {
        assert_eq!(a.len(), b.len());
        for (j, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "step {step} logit {j}: {x} vs {y} (not bit-identical)"
            );
        }
    }
}

/// Prefix-hit prefill: a second request with a shared prompt prefix skips
/// the matched blocks and still produces logits close to a cold run
/// (exact equality is not guaranteed once cached rows are re-read, but
/// the quantized format is stable enough that errors stay tiny).
#[test]
fn prefix_hit_prefill_matches_cold_prefill() {
    let (model, ..) = tiny_model(11);
    let paged = PagedEngine::new(model, 64, 4);
    let shared: Vec<u32> = (0..16u32).map(|i| (i * 13 + 5) % 256).collect();
    let mut prompt_a = shared.clone();
    prompt_a.extend_from_slice(&[7, 8, 9]);
    let mut prompt_b = shared.clone();
    prompt_b.extend_from_slice(&[200, 201]);

    // cold run of prompt_b on an independent engine (no prefix cache)
    let (model_cold, ..) = tiny_model(11);
    let cold = PagedEngine::new(model_cold, 64, 4);
    let mut seq_cold = cold.new_seq();
    let cold_logits = cold.try_prefill(&mut seq_cold, &prompt_b).expect("prefill");

    // warm engine: run prompt_a first, then prompt_b hits the shared
    // prefix blocks
    let mut seq_a = paged.new_seq();
    let _ = paged.try_prefill(&mut seq_a, &prompt_a).expect("prefill");
    let before = paged.stats();
    let mut seq_b = paged.new_seq();
    let warm_logits = paged.try_prefill(&mut seq_b, &prompt_b).expect("prefill");
    let after = paged.stats();

    assert!(
        after.prefix_hit_tokens > before.prefix_hit_tokens,
        "prompt_b should hit the shared prefix ({} vs {})",
        after.prefix_hit_tokens,
        before.prefix_hit_tokens
    );
    assert_eq!(after.prefix_hit_tokens - before.prefix_hit_tokens, 16);
    let mut max_err = 0.0f32;
    for (&x, &y) in cold_logits.iter().zip(&warm_logits) {
        max_err = max_err.max((x - y).abs());
    }
    assert!(max_err < 2e-2, "warm-vs-cold prefill logit err {max_err}");
}

/// Partial-block tail sharing: a prompt whose shared prefix ends
/// mid-block still hits — the shared leading rows of the sealed sibling
/// are copied into a fresh block, and only the true suffix is forwarded.
#[test]
fn partial_block_tail_prefix_hits_mid_block() {
    let (model, ..) = tiny_model(5);
    let paged = PagedEngine::new(model, 64, 4);
    let base: Vec<u32> = (0..10u32).map(|i| (i * 7 + 2) % 256).collect();
    let mut seq_a = paged.new_seq();
    let _ = paged.try_prefill(&mut seq_a, &base).expect("prefill");
    paged.release(&mut seq_a);

    // shares 6 tokens: block 0 fully + 2 rows into block 1
    let mut prompt_b = base[..6].to_vec();
    prompt_b.extend([201, 202, 203]);
    assert_eq!(paged.prefix_match_len(&prompt_b), 6);

    // cold reference on an independent engine (no prefix cache)
    let (model_cold, ..) = tiny_model(5);
    let cold = PagedEngine::new(model_cold, 64, 4);
    let mut seq_cold = cold.new_seq();
    let cold_logits = cold.try_prefill(&mut seq_cold, &prompt_b).expect("prefill");

    let before = paged.stats();
    let mut seq_b = paged.new_seq();
    let warm_logits = paged.try_prefill(&mut seq_b, &prompt_b).expect("prefill");
    let after = paged.stats();
    assert_eq!(after.prefix_hit_tokens - before.prefix_hit_tokens, 6);
    assert_eq!(after.prefix_partial_hits, 1);
    assert!(after.cow_copies >= 1);
    let mut max_err = 0.0f32;
    for (&x, &y) in cold_logits.iter().zip(&warm_logits) {
        max_err = max_err.max((x - y).abs());
    }
    assert!(max_err < 2e-2, "partial-hit prefill logit err {max_err}");
    paged.release(&mut seq_b);
}

#[test]
fn paged_engine_reports_capacity_and_releases() {
    let (model, ..) = tiny_model(3);
    // 4 blocks of 8 positions: fits one 20-token sequence, not three
    let paged = PagedEngine::new(model, 4, 8);
    let prompt: Vec<u32> = (0..20).collect();
    assert!(paged.can_admit(&prompt));
    let mut seq = paged.new_seq();
    let _ = paged.try_prefill(&mut seq, &prompt).expect("prefill");
    let s = paged.stats();
    assert_eq!(s.blocks_active, 3);
    assert!(paged.seq_bytes(&seq) > 0);
    // a distinct prompt needs 3 fresh blocks and only 1 is left
    let distinct: Vec<u32> = (100..120).collect();
    assert!(!paged.can_admit(&distinct), "3 of 4 blocks pinned");
    // ...but an identical prompt shares the 2 sealed prefix blocks and
    // is charged only its tail (prefix-aware admission)
    assert!(paged.can_admit(&prompt), "shared prefix fits the gap");
    // the tail block still has room, so the next decode token reserves
    // without allocating
    assert!(paged.reserve_decode(&mut seq));
    paged.release(&mut seq);
    assert!(paged.can_admit(&distinct), "release frees capacity");
    assert_eq!(paged.stats().blocks_active, 0);
}

/// Block-table storage roundtrips the same rows as the flat KvStore.
#[test]
fn block_table_roundtrip_matches_flat_store() {
    let mut rng = Pcg::new(42);
    let mut flat = KvStore::new(4, 8);
    let mut pool = KvPool::new(KvPoolConfig {
        n_blocks: 8,
        block_size: 4,
        n_layers: 1,
        kv_bits: 4,
        kv_group: 8,
    });
    let mut table = Vec::new();
    let rows: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(32)).collect();
    for (pos, row) in rows.iter().enumerate() {
        flat.push(row);
        pool.append_row(&mut table, 0, pos, row, row);
    }
    let mut flat_scratch = Vec::new();
    let flat_rows = flat.view(&mut flat_scratch);
    let mut ks = Vec::new();
    let mut vs = Vec::new();
    let (paged_rows, paged_vals) = pool.gather_rows(&table, 0, &mut ks, &mut vs);
    assert_eq!(flat_rows.len(), 10);
    assert_eq!(paged_rows.len(), 10);
    assert_eq!(paged_vals.len(), 10);
    for (pos, (f, p)) in flat_rows.iter().zip(paged_rows).enumerate() {
        for (j, (&a, &b)) in f.iter().zip(p).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "row {pos} col {j}: {a} vs {b}"
            );
        }
    }
}

fn argmax_u32(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}
