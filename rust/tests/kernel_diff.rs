//! Kernel-layer differential suite: every compiled backend (scalar,
//! portable, and avx2 when the host supports it) must produce
//! **bit-identical** results on the INT4 hot path —
//!
//! * packed-direct igemm == the unpacked `igemm_i8_bt` i32 accumulators,
//! * the fused RRS prologue + fused GEMM == the staged reference
//!   pipeline (`prepare_staged` + `forward_rs_fused_prepermuted`),
//! * the per-channel epilogue == `forward_per_channel_a4w4`,
//! * the FWHT butterflies == the scalar reference, and
//! * the f32 attention dot == `linalg::gemm::dot`.
//!
//! Shapes deliberately include odd K, K not divisible by the group /
//! tile sizes, and batch-1 decode rows.  CI runs this suite once more
//! with `RRS_KERNEL=scalar` forced so the reference backend itself stays
//! exercised on AVX2 runners (the dispatched entry points are covered by
//! the crate's unit/integration tests; this file sweeps `all_backends`).

use rrs::kernels::{self, KernelBackend, TileConfig};
use rrs::linalg::fwht::fwht_inplace_scalar;
use rrs::linalg::igemm::{igemm_i8_bt, MatI8};
use rrs::quant::pack4::PackedI4;
use rrs::quant::qlinear::{
    effective_group, forward_per_channel_a4w4, forward_per_channel_a8w4,
    forward_rs_fused_prepermuted,
};
use rrs::quant::{rtn, runtime_smooth, QMAX8};
use rrs::util::proptest::{check, Config};
use rrs::util::rng::Pcg;

fn rand_codes(rng: &mut Pcg, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.below(16) as i8 - 8).collect()
}

fn rand_mat(rng: &mut Pcg, r: usize, c: usize) -> rrs::linalg::gemm::Mat {
    rrs::linalg::gemm::Mat::from_vec(r, c, rng.normal_vec(r * c))
}

/// Tile shapes chosen to force partial tiles, tiny K blocks, and blocks
/// larger than the problem.
fn tile_grid() -> Vec<TileConfig> {
    vec![
        TileConfig::DEFAULT,
        TileConfig { mr: 1, nr: 1, kc: 32 },
        TileConfig { mr: 3, nr: 7, kc: 64 },
        TileConfig { mr: 16, nr: 128, kc: 4096 },
    ]
}

#[test]
fn packed_igemm_matches_unpacked_bitwise() {
    // includes K odd / prime / not divisible by any tile or group size
    check("kdiff-igemm", Config { cases: 48, ..Config::default() }, |rng, case| {
        let n = 1 + rng.below(6);
        let k = [1, 2, 3, 17, 31, 32, 33, 64, 97, 130][case % 10] + rng.below(8);
        let m = 1 + rng.below(12);
        let a = MatI8::from_vec(n, k, rand_codes(rng, n * k));
        let b = MatI8::from_vec(m, k, rand_codes(rng, m * k));
        let bp = PackedI4::pack(&b);
        let want = igemm_i8_bt(&a, &b);
        for bk in kernels::all_backends() {
            for tiles in tile_grid() {
                let got = kernels::igemm_packed_with(bk, tiles, &a, &bp);
                if got != want {
                    return Err(format!(
                        "{} tiles {} diverged on n={n} k={k} m={m}",
                        bk.name(),
                        tiles.label()
                    ));
                }
            }
        }
        Ok(())
    });
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{what}: bit mismatch at {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn fused_rrs_pipeline_matches_staged_bitwise() {
    check("kdiff-rrs", Config { cases: 32, ..Config::default() }, |rng, case| {
        let n = 1 + rng.below(5);
        let k = [32, 64, 96, 128, 160, 256][case % 6];
        let m = 1 + rng.below(10);
        // groups including 1 (exact RS), odd-ish, and K itself; snapped
        // to a divisor of K exactly like the serving path
        let group = effective_group([1, 8, 24, 32, 64, k][case % 6], k);
        let x = rand_mat(rng, n, k);
        let w = rand_mat(rng, m, k);
        let (wq, sw) = rtn::quant_per_channel_w(&w);

        // staged oracle
        let sa = runtime_smooth::prepare_staged(&x, group);
        let wqp = wq.permute_cols(&sa.perm);
        let want = forward_rs_fused_prepermuted(&sa, &wqp, &sw);
        let bp = PackedI4::pack(&wqp);

        for bk in kernels::all_backends() {
            // fused prologue must reproduce the staged one exactly
            let fa = kernels::rrs_prologue_with(bk, &x, group);
            if fa.q.data != sa.q.data || fa.perm != sa.perm {
                return Err(format!("{}: prologue codes/perm diverged", bk.name()));
            }
            assert_bits(
                &fa.token_scales,
                &sa.token_scales,
                &format!("{} token scales", bk.name()),
            )?;
            assert_bits(
                &fa.group_scales,
                &sa.group_scales,
                &format!("{} group scales", bk.name()),
            )?;
            // fused GEMM must reproduce the staged epilogue exactly
            for tiles in tile_grid() {
                let got = kernels::gemm_rs_fused_packed_with(
                    bk,
                    tiles,
                    &fa.q,
                    &fa.token_scales,
                    fa.group,
                    &fa.group_scales,
                    &bp,
                    &sw,
                );
                assert_bits(
                    &got.data,
                    &want.data,
                    &format!("{} tiles {} fused rrs", bk.name(), tiles.label()),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn per_channel_matches_staged_bitwise() {
    check("kdiff-perchannel", Config { cases: 32, ..Config::default() }, |rng, case| {
        let n = 1 + rng.below(6);
        let k = [8, 16, 33, 64, 100, 128][case % 6];
        let m = 1 + rng.below(10);
        let x = rand_mat(rng, n, k);
        let w = rand_mat(rng, m, k);
        let (wq, sw) = rtn::quant_per_channel_w(&w);
        let want = forward_per_channel_a4w4(&x, &wq, &sw);
        let (xq, sx) = rtn::quant_per_token(&x);
        let bp = PackedI4::pack(&wq);
        for bk in kernels::all_backends() {
            for tiles in tile_grid() {
                let got = kernels::gemm_per_channel_packed_with(
                    bk, tiles, &xq, &sx, &bp, &sw,
                );
                assert_bits(
                    &got.data,
                    &want.data,
                    &format!("{} tiles {} per-channel", bk.name(), tiles.label()),
                )?;
            }
        }
        Ok(())
    });
}

/// W4A8 oracle: the registered microkernel entry
/// (`kernels::gemm_w4a8_packed`) must reproduce the staged INT8
/// reference (`forward_per_channel_a8w4`) bit-for-bit on every backend
/// and tile shape.  Activations are quantized at qmax 127, so the codes
/// span the full INT8 range — this is the case an i16-multiply kernel
/// path would silently overflow on.
#[test]
fn w4a8_matches_staged_reference_bitwise() {
    check("kdiff-w4a8", Config { cases: 32, ..Config::default() }, |rng, case| {
        let n = 1 + rng.below(6);
        let k = [8, 16, 33, 64, 100, 128][case % 6];
        let m = 1 + rng.below(10);
        let x = rand_mat(rng, n, k);
        let w = rand_mat(rng, m, k);
        let (wq, sw) = rtn::quant_per_channel_w(&w);
        let want = forward_per_channel_a8w4(&x, &wq, &sw);
        let (xq, sx) = rtn::quant_per_token_q(&x, QMAX8);
        // sanity: quantizing a continuous row at 127 actually exercises
        // codes beyond the INT4 range
        assert!(
            xq.data.iter().any(|&c| c.abs() > 7),
            "INT8 quantization produced only INT4-range codes (k={k})"
        );
        let bp = PackedI4::pack(&wq);
        for bk in kernels::all_backends() {
            for tiles in tile_grid() {
                let got = kernels::gemm_w4a8_packed_with(bk, tiles, &xq, &sx, &bp, &sw);
                assert_bits(
                    &got.data,
                    &want.data,
                    &format!("{} tiles {} w4a8", bk.name(), tiles.label()),
                )?;
            }
        }
        Ok(())
    });
}

/// Extreme-magnitude W4A8 codes: saturated ±127 activations against
/// saturated ±7 weights — the worst case for any widening multiply.
#[test]
fn w4a8_saturated_codes_stay_exact() {
    let (n, k, m) = (3usize, 96usize, 5usize);
    let xq = MatI8::from_vec(
        n,
        k,
        (0..n * k).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect(),
    );
    let wq = MatI8::from_vec(
        m,
        k,
        (0..m * k).map(|i| if i % 3 == 0 { 7 } else { -7 }).collect(),
    );
    let sx = vec![0.013f32; n];
    let sw: Vec<f32> = (0..m).map(|j| 0.05 + j as f32 * 0.01).collect();
    let bp = PackedI4::pack(&wq);
    // exact i32 reference from the unpacked igemm
    let acc = igemm_i8_bt(&xq, &wq);
    for bk in kernels::all_backends() {
        for tiles in tile_grid() {
            let got = kernels::gemm_w4a8_packed_with(bk, tiles, &xq, &sx, &bp, &sw);
            for i in 0..n {
                for j in 0..m {
                    let want = acc[i * m + j] as f32 * sx[i] * sw[j];
                    let g = got.data[i * m + j];
                    assert_eq!(
                        g.to_bits(),
                        want.to_bits(),
                        "{} tiles {} saturated w4a8 at ({i},{j}): {g} vs {want}",
                        bk.name(),
                        tiles.label()
                    );
                }
            }
        }
    }
}

/// The INT8 (qmax 127) RRS prologue must match the staged reference on
/// every backend, exactly like the INT4 one — codes, permutation and
/// both scale vectors.
#[test]
fn int8_prologue_matches_staged_bitwise() {
    check("kdiff-prologue8", Config { cases: 24, ..Config::default() }, |rng, case| {
        let n = 1 + rng.below(5);
        let k = [32, 64, 96, 128][case % 4];
        let group = effective_group([1, 8, 32, k][case % 4], k);
        let x = rand_mat(rng, n, k);
        let want = runtime_smooth::prepare_staged_q(&x, group, QMAX8);
        for bk in kernels::all_backends() {
            let got = kernels::rrs_prologue_with_q(bk, &x, group, QMAX8);
            if got.q.data != want.q.data || got.perm != want.perm {
                return Err(format!("{}: int8 prologue codes/perm diverged", bk.name()));
            }
            assert_bits(
                &got.token_scales,
                &want.token_scales,
                &format!("{} int8 token scales", bk.name()),
            )?;
            assert_bits(
                &got.group_scales,
                &want.group_scales,
                &format!("{} int8 group scales", bk.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn fwht_backends_match_scalar_bitwise_and_involute() {
    check("kdiff-fwht", Config { cases: 48, ..Config::default() }, |rng, case| {
        let k = 1usize << (case % 10); // 1 .. 512
        let x0 = rng.normal_vec(k);
        let mut want = x0.clone();
        fwht_inplace_scalar(&mut want);
        for bk in kernels::all_backends() {
            let mut got = x0.clone();
            bk.fwht(&mut got);
            assert_bits(&got, &want, &format!("{} fwht k={k}", bk.name()))?;
            // involution sanity on the backend's own output
            bk.fwht(&mut got);
            for (a, b) in got.iter().zip(&x0) {
                if (a - b).abs() > 1e-3 {
                    return Err(format!(
                        "{} fwht k={k} not an involution: {a} vs {b}",
                        bk.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn dot_f32_matches_reference_bitwise() {
    check("kdiff-dot", Config { cases: 64, ..Config::default() }, |rng, _| {
        let len = 1 + rng.below(70);
        let a = rng.normal_vec(len);
        let b = rng.normal_vec(len);
        let want = rrs::linalg::gemm::dot(&a, &b);
        for bk in kernels::all_backends() {
            let got = bk.dot_f32(&a, &b);
            if got.to_bits() != want.to_bits() {
                return Err(format!(
                    "{} dot len={len}: {got} vs {want}",
                    bk.name()
                ));
            }
        }
        Ok(())
    });
}

/// The dispatched serving path (whatever `RRS_KERNEL` selected) agrees
/// with the staged reference end-to-end — this is the invocation CI
/// repeats with `RRS_KERNEL=scalar`.
#[test]
fn dispatched_backend_matches_staged_reference() {
    let mut rng = Pcg::new(0xD1FF);
    let x = rand_mat(&mut rng, 4, 128);
    let w = rand_mat(&mut rng, 24, 128);
    let (wq, sw) = rtn::quant_per_channel_w(&w);
    let group = 32;
    let sa = runtime_smooth::prepare(&x, group); // dispatched prologue
    let staged = runtime_smooth::prepare_staged(&x, group);
    assert_eq!(sa.q.data, staged.q.data);
    assert_eq!(sa.perm, staged.perm);
    let wqp = wq.permute_cols(&sa.perm);
    let want = forward_rs_fused_prepermuted(&staged, &wqp, &sw);
    let got = kernels::gemm_rs_fused_packed(
        &sa.q,
        &sa.token_scales,
        sa.group,
        &sa.group_scales,
        &PackedI4::pack(&wqp),
        &sw,
    );
    assert_bits(&got.data, &want.data, "dispatched fused rrs").unwrap();
    eprintln!(
        "dispatched backend: {} (tile {})",
        kernels::stats().backend,
        kernels::stats().tiles.label()
    );
}
