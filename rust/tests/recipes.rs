//! Scenario-matrix smoke suite: every composable recipe combination
//! ([`QuantRecipe::matrix`]) drives a tiny model through one prefill +
//! decode step, the W4A8 serving path is diffed against its staged
//! oracle at the layer level, rotated recipes are pushed through
//! non-power-of-two engine dims (the fwht-panic regression), and the
//! parse grammar round-trips.  The final test writes the smoke-scale
//! `BENCH_matrix.json` ablation report at the repo root (CI uploads it
//! and diffs it against the committed baseline).

use rrs::harness::matrix::{to_json, MatrixCell};
use rrs::linalg::gemm::Mat;
use rrs::model::{EngineConfig, KvCache, ModelConfig, QuantModel, Weights};
use rrs::quant::qlinear::{self, PrepareAux, QLinear};
use rrs::quant::{rtn, QuantRecipe, RotationKind, Smoothing};
use rrs::util::bench::bench_output_path;
use rrs::util::rng::Pcg;

fn tiny_cfg() -> ModelConfig {
    ModelConfig { n_layers: 1, max_seq: 64, ..Default::default() }
}

fn calib_tokens(mcfg: &ModelConfig) -> Vec<u32> {
    (0..256u32).map(|i| (i * 53 + 7) % mcfg.vocab as u32).collect()
}

/// Prefill + one decode step under a recipe; returns the decode logits.
fn prefill_and_decode(
    mcfg: &ModelConfig,
    w: &Weights,
    recipe: QuantRecipe,
    calib: &[u32],
) -> anyhow::Result<Mat> {
    let ecfg = EngineConfig::from_recipe(recipe);
    let model = QuantModel::prepare(w, mcfg, &ecfg, Some(calib), None)?;
    let prompt: Vec<u32> = (1u32..9).collect();
    let mut cache = KvCache::new(mcfg, &ecfg);
    let logits = model.forward_full(&prompt, Some(&mut cache));
    assert!(
        logits.data.iter().all(|v| v.is_finite()),
        "{}: non-finite prefill logits",
        recipe.label()
    );
    let mut batch = [(&mut cache, 3u32)];
    let step = model.decode_batch(&mut batch);
    assert!(
        step.data.iter().all(|v| v.is_finite()),
        "{}: non-finite decode logits",
        recipe.label()
    );
    Ok(step)
}

/// Every ablation-grid recipe survives one prefill + decode step on the
/// tiny model — the CI scenario-matrix gate.
#[test]
fn every_matrix_recipe_runs_prefill_and_decode() {
    let mcfg = tiny_cfg();
    let w = Weights::random(&mcfg, 11);
    let calib = calib_tokens(&mcfg);
    let cells = QuantRecipe::matrix();
    assert!(cells.len() >= 6, "ablation grid shrank to {} cells", cells.len());
    for recipe in cells {
        prefill_and_decode(&mcfg, &w, recipe, &calib)
            .unwrap_or_else(|e| panic!("{}: {e}", recipe.label()));
    }
}

/// The grid must keep the three headline combos the report is built
/// around: RRS W4A4, SmoothQuant W4A8, and a rotation-only variant.
#[test]
fn matrix_covers_required_combos() {
    let cells = QuantRecipe::matrix();
    assert!(cells.iter().any(|r| r.smoothing == Smoothing::Runtime
        && r.rotation == RotationKind::Hadamard
        && r.a_bits == 4
        && r.w_bits == 4
        && r.kv_bits == 4));
    assert!(cells
        .iter()
        .any(|r| r.smoothing == Smoothing::Calibrated && r.a_bits == 8 && r.w_bits == 4));
    assert!(cells
        .iter()
        .any(|r| r.smoothing == Smoothing::None && r.rotation != RotationKind::None));
    // every cell is valid and distinct
    for (i, a) in cells.iter().enumerate() {
        a.validate().unwrap();
        for b in &cells[i + 1..] {
            assert_ne!(a, b, "duplicate matrix cell {}", a.label());
        }
    }
}

/// Rotated recipes on non-power-of-two engine dims must prepare and run
/// via the block-Hadamard fallback — never hit the fwht power-of-two
/// assert at runtime.
#[test]
fn non_pow2_dims_never_panic() {
    let mcfg = ModelConfig {
        dim: 96,
        ffn: 144,
        n_heads: 4,
        n_kv_heads: 2,
        n_layers: 1,
        max_seq: 64,
        ..Default::default()
    };
    let w = Weights::random(&mcfg, 23);
    let calib = calib_tokens(&mcfg);
    for spec in ["rrs:g32:nogptq", "quarot:g32:nogptq", "dense:g32:nogptq", "sq:had:g32:nogptq"]
    {
        let recipe = QuantRecipe::parse(spec).unwrap();
        prefill_and_decode(&mcfg, &w, recipe, &calib)
            .unwrap_or_else(|e| panic!("{spec} on 96/144 dims: {e}"));
    }
}

/// Layer-level W4A8 bit-identity: a QLinear prepared under an INT8
/// activation recipe serves the registered W4A8 microkernel, which must
/// reproduce the staged reference exactly.
#[test]
fn w4a8_layer_matches_staged_oracle_bitwise() {
    let mut rng = Pcg::new(0xA8);
    let (n, k, m) = (5usize, 64usize, 24usize);
    let x = Mat::from_vec(n, k, rng.normal_vec(n * k));
    let w = Mat::from_vec(m, k, rng.normal_vec(m * k));
    let recipe = QuantRecipe::parse("rtn:a8w4kv16:nogptq").unwrap();
    let layer = QLinear::prepare_recipe(&w, &recipe, PrepareAux::default()).unwrap();
    let got = layer.forward(&x);
    let (wq, sw) = rtn::quant_per_channel_w(&w);
    let want = qlinear::forward_per_channel_a8w4(&x, &wq, &sw);
    assert_eq!(got.data, want.data, "W4A8 layer diverged from staged oracle");
}

/// Parse grammar: axis tokens compose over the defaults and the derived
/// legacy label stays in sync with the engine config.
#[test]
fn recipe_parse_and_labels_round_trip() {
    let r = QuantRecipe::parse("sq:a8w4kv8:had:g64:kvg16:alpha0.7:nogptq").unwrap();
    assert_eq!(r.smoothing, Smoothing::Calibrated);
    assert_eq!(r.rotation, RotationKind::Hadamard);
    assert_eq!((r.a_bits, r.w_bits, r.kv_bits), (8, 4, 8));
    assert_eq!((r.group, r.kv_group), (64, 16));
    assert!((r.alpha - 0.7).abs() < 1e-6);
    assert!(!r.gptq);
    for recipe in QuantRecipe::matrix() {
        let ecfg = EngineConfig::from_recipe(recipe);
        assert_eq!(ecfg.label(), recipe.label());
        assert_eq!(ecfg.resolved(), recipe);
    }
    assert!(QuantRecipe::parse("a7w4kv4").is_err());
    assert!(QuantRecipe::parse("bogus-token").is_err());
}

/// Smoke-scale ablation report: sweep the grid on the tiny model,
/// measure perplexity + decode throughput, and write `BENCH_matrix.json`
/// at the repo root for CI to diff and upload.
#[test]
fn matrix_smoke_writes_ablation_report() {
    let mcfg = tiny_cfg();
    let w = Weights::random(&mcfg, 31);
    let calib = calib_tokens(&mcfg);
    let text = "the quick brown fox jumps over the lazy dog. ".repeat(16);
    let mut cells = Vec::new();
    for recipe in QuantRecipe::matrix() {
        let ecfg = EngineConfig::from_recipe(recipe);
        let model = QuantModel::prepare(&w, &mcfg, &ecfg, Some(&calib), None).unwrap();
        let ppl = rrs::eval::perplexity(&model, &text, 32, 2);
        assert!(ppl.is_finite(), "{}: non-finite smoke ppl", recipe.label());
        let prompt: Vec<u32> = (1u32..9).collect();
        let mut cache = KvCache::new(&mcfg, &ecfg);
        model.forward_full(&prompt, Some(&mut cache));
        let steps = 16usize;
        let t0 = std::time::Instant::now();
        let mut tok = 3u32;
        for _ in 0..steps {
            let mut batch = [(&mut cache, tok)];
            let logits = model.decode_batch(&mut batch);
            tok = (logits.row(0)[0].abs() as u32 % 250) + 1;
        }
        let tps = steps as f32 / t0.elapsed().as_secs_f32().max(1e-9);
        // QA accuracy is meaningless on a random model; the smoke report
        // carries 0.0 and the `smoke` flag so consumers know not to
        // compare it against the trained-artifact sweep
        cells.push(MatrixCell { recipe, ppl, qa_avg: 0.0, decode_tps: tps });
    }
    let path = bench_output_path("BENCH_matrix.json");
    std::fs::write(&path, to_json(&cells, true).dump()).unwrap();
    eprintln!("wrote {} ({} cells)", path.display(), cells.len());
}
