//! End-to-end streaming over the live TCP front-end: token frames agree
//! with the terminal response, stop sequences span token boundaries,
//! stop ids win the boundary race against max_tokens, malformed params
//! get error replies without killing the connection, and a mid-stream
//! disconnect cancels the lane and frees its KV blocks.  Runs entirely
//! on a small random model — no artifacts needed.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use rrs::coordinator::{
    server, Coordinator, RequestOptions, RustServeEngine, SamplingParams,
    SchedulerConfig,
};
use rrs::kvpool::PagedEngine;
use rrs::model::sampler::Sampling;
use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::util::json::Json;

fn tiny_model() -> QuantModel {
    let cfg = ModelConfig { n_layers: 2, max_seq: 96, ..Default::default() };
    let w = Weights::random(&cfg, 42);
    let calib: Vec<u32> = (0..128u32).map(|i| (i * 53 + 7) % 256).collect();
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV4,
        group: 32,
        gptq: false,
        ..Default::default()
    };
    QuantModel::prepare(&w, &cfg, &ecfg, Some(&calib), None).unwrap()
}

fn flat_server() -> (u16, JoinHandle<()>, Arc<Coordinator>) {
    let coord = Arc::new(Coordinator::start(
        RustServeEngine::new(tiny_model()),
        SchedulerConfig { max_batch: 4, ..Default::default() },
    ).expect("start coordinator"));
    let (port, handle) = server::spawn(coord.clone(), "127.0.0.1:0").unwrap();
    (port, handle, coord)
}

fn paged_server(blocks: usize) -> (u16, JoinHandle<()>, Arc<Coordinator>) {
    let coord = Arc::new(Coordinator::start(
        PagedEngine::new(tiny_model(), blocks, 8),
        SchedulerConfig { max_batch: 4, ..Default::default() },
    ).expect("start coordinator"));
    let (port, handle) = server::spawn(coord.clone(), "127.0.0.1:0").unwrap();
    (port, handle, coord)
}

/// One newline-delimited-JSON protocol connection.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(port: u16) -> Client {
        let mut last = None;
        for _ in 0..40 {
            match TcpStream::connect(("127.0.0.1", port)) {
                Ok(s) => {
                    let reader = BufReader::new(s.try_clone().unwrap());
                    return Client { stream: s, reader };
                }
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
        panic!("could not connect to 127.0.0.1:{port}: {last:?}");
    }

    fn send(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
    }

    fn recv(&mut self) -> Json {
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf).unwrap();
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(buf.trim()).unwrap_or_else(|e| panic!("bad frame {buf:?}: {e}"))
    }

    fn req(&mut self, line: &str) -> Json {
        self.send(line);
        self.recv()
    }

    /// Read frames until the one with `"done": true`; returns
    /// (token_frames, done_frame).
    fn recv_stream(&mut self) -> (Vec<Json>, Json) {
        let mut frames = Vec::new();
        loop {
            let f = self.recv();
            assert!(f.get("error").is_none(), "error frame: {}", f.dump());
            if f.get("done").and_then(Json::as_bool) == Some(true) {
                return (frames, f);
            }
            frames.push(f);
        }
    }
}

fn shutdown_server(port: u16, handle: JoinHandle<()>) {
    let mut c = Client::connect(port);
    let ok = c.req(r#"{"cmd": "shutdown"}"#);
    assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
    // one extra connection unblocks the accept loop
    let _ = TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}

/// Poll `{"cmd": "metrics"}` until `pred` holds (or panic at timeout).
fn wait_for_metrics(
    port: u16,
    what: &str,
    timeout: Duration,
    pred: impl Fn(&Json) -> bool,
) -> Json {
    let mut c = Client::connect(port);
    let t0 = Instant::now();
    loop {
        let snap = c.req(r#"{"cmd": "metrics"}"#);
        if pred(&snap) {
            return snap;
        }
        assert!(
            t0.elapsed() < timeout,
            "timed out waiting for {what}; last snapshot: {}",
            snap.dump()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn stream_frames_agree_with_terminal_response() {
    let (port, handle, _coord) = flat_server();
    let mut c = Client::connect(port);

    // free-running greedy: structural invariants on the frame stream
    c.send(r#"{"prompt": "arlo", "max_tokens": 6, "stream": true}"#);
    let (frames, done) = c.recv_stream();
    assert_eq!(frames.len(), 6);
    let id = done.get("id").unwrap().as_usize().unwrap();
    for (i, f) in frames.iter().enumerate() {
        assert_eq!(f.get("id").unwrap().as_usize(), Some(id), "{}", f.dump());
        assert_eq!(f.get("index").unwrap().as_usize(), Some(i), "gap in stream");
        assert!(f.get("token").unwrap().as_usize().unwrap() < 256);
    }
    assert_eq!(done.get("tokens").unwrap().as_usize(), Some(6));
    assert_eq!(done.get("finish").unwrap().as_str(), Some("max_tokens"));

    // forced-ASCII stream ('q' biased to dominate): the concatenated
    // frame texts must equal the terminal text byte-for-byte
    c.send(
        r#"{"prompt": "ab", "max_tokens": 5, "stream": true,
            "logit_bias": {"113": 1000000.0}}"#,
    );
    let (frames, done) = c.recv_stream();
    let cat: String = frames
        .iter()
        .map(|f| f.get("text").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(cat, "qqqqq");
    assert_eq!(done.get("text").unwrap().as_str(), Some("qqqqq"));
    shutdown_server(port, handle);
}

#[test]
fn stream_multi_choice_frames_carry_choice() {
    let (port, handle, _coord) = flat_server();
    let mut c = Client::connect(port);
    c.send(
        r#"{"prompt": "arlo", "max_tokens": 4, "n": 2, "stream": true,
            "temperature": 1.0, "seed": 9}"#,
    );
    let mut done_choices = Vec::new();
    let mut token_frames = 0usize;
    while done_choices.len() < 2 {
        let f = c.recv();
        assert!(f.get("error").is_none(), "{}", f.dump());
        let choice = f.get("choice").unwrap().as_usize().unwrap();
        if f.get("done").and_then(Json::as_bool) == Some(true) {
            done_choices.push(choice);
        } else {
            token_frames += 1;
        }
    }
    done_choices.sort_unstable();
    assert_eq!(done_choices, vec![0, 1]);
    assert_eq!(token_frames, 8, "4 tokens per choice, every frame streamed");

    // blocking n=2 returns a choices array with per-choice finishes
    let resp = c.req(
        r#"{"prompt": "arlo", "max_tokens": 4, "n": 2,
            "temperature": 1.0, "seed": 9}"#,
    );
    let choices = resp.get("choices").unwrap().as_arr().unwrap();
    assert_eq!(choices.len(), 2);
    for ch in choices {
        assert_eq!(ch.get("tokens").unwrap().as_usize(), Some(4));
    }
    shutdown_server(port, handle);
}

#[test]
fn stop_sequence_spans_token_boundaries() {
    let (port, handle, coord) = flat_server();
    let mut c = Client::connect(port);

    // byte-level tokenizer: the two-byte stop string "qq" can only match
    // across two token boundaries.  Bias forces greedy onto 'q'.
    let resp = c.req(
        r#"{"prompt": "ab", "max_tokens": 16, "stop": ["qq"],
            "logit_bias": {"113": 1000000.0}}"#,
    );
    assert_eq!(resp.get("finish").unwrap().as_str(), Some("stop_seq"));
    assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(2));
    assert_eq!(resp.get("text").unwrap().as_str(), Some("qq"));

    // same property on an unforced stream: probe the greedy output, then
    // stop on a 3-token window starting mid-stream
    let probe = coord.generate(vec![5, 6, 7], 6, Sampling::Greedy, None).unwrap();
    let stop_toks = probe.tokens[1..4].to_vec();
    let resp = coord
        .generate_opts(
            vec![5, 6, 7],
            RequestOptions {
                max_new_tokens: 16,
                params: SamplingParams {
                    stop_sequences: vec![stop_toks],
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(
        resp.finish_reason,
        rrs::coordinator::request::FinishReason::StopSequence
    );
    assert_eq!(resp.tokens, probe.tokens[..4].to_vec());
    shutdown_server(port, handle);
}

#[test]
fn stop_id_wins_race_against_max_tokens() {
    let (port, handle, coord) = flat_server();
    let first = coord
        .generate(vec![97, 98], 1, Sampling::Greedy, None)
        .unwrap()
        .tokens[0];
    // both stop conditions fire on the same (first) token: the stop id
    // must win the boundary race, for streaming and blocking alike
    let mut c = Client::connect(port);
    let resp = c.req(&format!(
        r#"{{"prompt": "ab", "max_tokens": 1, "stop_token_ids": [{first}]}}"#
    ));
    assert_eq!(resp.get("finish").unwrap().as_str(), Some("stop"));
    assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(1));

    let resp = coord
        .generate_opts(
            vec![97, 98],
            RequestOptions {
                max_new_tokens: 1,
                params: SamplingParams {
                    stop_token_ids: vec![first],
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(
        resp.finish_reason,
        rrs::coordinator::request::FinishReason::StopToken
    );
    shutdown_server(port, handle);
}

#[test]
fn malformed_params_get_error_replies() {
    let (port, handle, _coord) = flat_server();
    let mut c = Client::connect(port);
    for bad in [
        r#"{"prompt": "a", "temperature": "hot"}"#,
        r#"{"prompt": "a", "top_p": 0.0}"#,
        r#"{"prompt": "a", "top_k": -1}"#,
        r#"{"prompt": "a", "logit_bias": [1, 2]}"#,
        r#"{"prompt": "a", "stop": 5}"#,
        r#"{"prompt": "a", "n": 0}"#,
        r#"{"prompt": "a", "deadline_ms": -20}"#,
        r#"{"prompt": "a", "stream": true, "seed": 1.5}"#,
        r#"not json"#,
    ] {
        let resp = c.req(bad);
        assert!(
            resp.get("error").is_some(),
            "no error for {bad}: {}",
            resp.dump()
        );
    }
    // the connection survives every rejection
    let ok = c.req(r#"{"prompt": "a", "max_tokens": 2}"#);
    assert_eq!(ok.get("tokens").unwrap().as_usize(), Some(2));
    shutdown_server(port, handle);
}

#[test]
fn disconnect_mid_stream_cancels_lane_and_frees_blocks() {
    let (port, handle, coord) = paged_server(24);
    let mut c = Client::connect(port);
    c.send(
        r#"{"prompt": "abcd", "max_tokens": 80, "stream": true,
            "temperature": 0.7, "seed": 3}"#,
    );
    // take two frames, then vanish mid-stream
    let _ = c.recv();
    let _ = c.recv();
    c.stream.shutdown(Shutdown::Both).unwrap();
    drop(c);

    // the scheduler must notice (failed frame write -> abort -> retire
    // as cancelled) and the pool must drain back to zero used blocks
    let snap = wait_for_metrics(
        port,
        "disconnect cancellation + block reclaim",
        Duration::from_secs(30),
        |snap| {
            let cancelled =
                snap.get("cancelled").and_then(Json::as_usize).unwrap_or(0);
            let used = snap
                .get("kv_pool")
                .and_then(|p| p.get("blocks_used"))
                .and_then(Json::as_usize)
                .unwrap_or(usize::MAX);
            cancelled >= 1 && used == 0
        },
    );
    assert_eq!(snap.get("completed").unwrap().as_usize(), Some(0));
    assert!(coord.metrics.cancelled.load(Ordering::Relaxed) >= 1);

    // the lifecycle trace recorded the abort
    let mut c = Client::connect(port);
    let doc = c.req(r#"{"cmd": "trace", "format": "jsonl"}"#);
    let body = doc.get("body").unwrap().as_str().unwrap();
    assert!(body.contains("abort"), "no abort span in trace:\n{body}");
    shutdown_server(port, handle);
}

#[test]
fn churn_leaves_no_hung_lanes() {
    let (port, handle, coord) = paged_server(48);
    let mut joins = Vec::new();
    for i in 0..16usize {
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(port);
            match i % 4 {
                // blocking request, two choices
                0 => {
                    let resp = c.req(
                        r#"{"prompt": "arlo is", "max_tokens": 6, "n": 2,
                            "temperature": 0.8, "seed": 11}"#,
                    );
                    assert!(resp.get("choices").is_some(), "{}", resp.dump());
                }
                // streamed to completion
                1 => {
                    c.send(
                        r#"{"prompt": "count: 1 2", "max_tokens": 8,
                            "stream": true, "temperature": 1.0}"#,
                    );
                    let (_, done) = c.recv_stream();
                    assert_eq!(done.get("tokens").unwrap().as_usize(), Some(8));
                }
                // dropper: reads one frame, disconnects
                2 => {
                    c.send(
                        r#"{"prompt": "the fox", "max_tokens": 64,
                            "stream": true, "temperature": 1.0}"#,
                    );
                    let _ = c.recv();
                    let _ = c.stream.shutdown(Shutdown::Both);
                }
                // tight deadline: finishes as deadline or completes
                _ => {
                    let resp = c.req(
                        r#"{"prompt": "senna", "max_tokens": 48,
                            "deadline_ms": 30}"#,
                    );
                    assert!(
                        resp.get("finish").is_some(),
                        "{}",
                        resp.dump()
                    );
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // every submission must reach a terminal state and every block must
    // come back — the no-hung-lanes ledger
    wait_for_metrics(port, "ledger to balance", Duration::from_secs(30), |snap| {
        let n = |k: &str| snap.get(k).and_then(Json::as_usize).unwrap_or(0);
        let used = snap
            .get("kv_pool")
            .and_then(|p| p.get("blocks_used"))
            .and_then(Json::as_usize)
            .unwrap_or(usize::MAX);
        n("submitted") > 0
            && n("submitted")
                == n("completed")
                    + n("cancelled")
                    + n("aborted")
                    + n("deadline_missed")
                    + n("rejected")
            && used == 0
    });
    assert!(coord.metrics.completed.load(Ordering::Relaxed) >= 1);
    shutdown_server(port, handle);
}
