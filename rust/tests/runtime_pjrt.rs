//! Integration: PJRT runtime executes the AOT artifacts and reproduces the
//! python-side golden outputs — proving L1 (Pallas kernel) -> L2 (JAX
//! model) -> HLO text -> rust PJRT compose end-to-end.
//!
//! Requires `make artifacts`.

use rrs::model::{EngineConfig, ModelConfig, QuantModel, Weights};
use rrs::quant::{Method, Scheme};
use rrs::runtime::PjrtEngine;
use rrs::util::io::read_rrsw;

fn artifacts_root() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn have_artifacts() -> bool {
    std::path::Path::new(artifacts_root()).join("manifest.json").exists()
}

macro_rules! need_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn demo_rrs_gemm_artifact_matches_golden() {
    need_artifacts!();
    let engine = PjrtEngine::new(artifacts_root()).unwrap();
    let goldens = read_rrsw(engine.artifacts.goldens_path()).unwrap();
    let x = goldens["demo_x"].as_f32().unwrap();
    let runner = engine.runner("demo_rrs_gemm").unwrap();
    let input = rrs::runtime::executor::HostTensor::f32(vec![16, 128], x.to_vec());
    let out = runner.run(&[input]).unwrap();
    let got = out[0].as_f32().unwrap();
    let want = goldens["demo_y"].as_f32().unwrap();
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() < 1e-3 + 1e-4 * w.abs(), "idx {i}: {g} vs {w}");
    }
}

#[test]
fn prefill_artifacts_match_python_goldens() {
    need_artifacts!();
    let engine = PjrtEngine::new(artifacts_root()).unwrap();
    let goldens = read_rrsw(engine.artifacts.goldens_path()).unwrap();
    let tokens: Vec<i32> = goldens["prefill_tokens"].as_i32().unwrap().to_vec();
    // fp/rtn: same computation on both XLA versions -> tight.  rrs: the
    // eager-python golden vs the cross-version-compiled graph can flip
    // borderline INT4 codes (argsort ties, half-step rounds), so the
    // comparison is correlation + bounded drift rather than allclose.
    for (variant, tight) in [("fp", true), ("rtn", true), ("rrs", false)] {
        let logits = engine.prefill(variant, &tokens).unwrap();
        let got = logits.as_f32().unwrap();
        let want = goldens[&format!("prefill_logits_{variant}")]
            .as_f32()
            .unwrap();
        assert_eq!(got.len(), want.len());
        let mut worst = 0.0f32;
        for (&g, &w) in got.iter().zip(want) {
            worst = worst.max((g - w).abs());
        }
        if tight {
            assert!(worst < 2e-3, "prefill_{variant}: max err {worst}");
        } else {
            let corr = correlation(got, want);
            assert!(corr > 0.999, "prefill_{variant}: corr {corr}");
            assert!(worst < 2.0, "prefill_{variant}: max err {worst}");
        }
        eprintln!("prefill_{variant}: max err {worst}");
    }
}

#[test]
fn decode_graph_continues_prefill() {
    need_artifacts!();
    let engine = PjrtEngine::new(artifacts_root()).unwrap();
    let b = engine.artifacts.decode_batch;
    let mut state = engine.new_kv_state();
    // feed a short prompt token-by-token through the decode graph
    let prompt: Vec<i32> = vec![97, 114, 108, 111]; // "arlo"
    let mut logits = Vec::new();
    for &t in &prompt {
        logits = engine
            .decode_step("fp", &vec![t; b], &mut state)
            .unwrap();
    }
    assert_eq!(state.pos, prompt.len());
    assert_eq!(logits.len(), b * engine.artifacts.model.vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
    // all batch lanes got identical tokens -> identical logits
    let v = engine.artifacts.model.vocab;
    for lane in 1..b {
        for j in 0..v {
            assert!((logits[j] - logits[lane * v + j]).abs() < 1e-4);
        }
    }
}

#[test]
fn rust_engine_fp_matches_pjrt_fp() {
    need_artifacts!();
    let engine = PjrtEngine::new(artifacts_root()).unwrap();
    let goldens = read_rrsw(engine.artifacts.goldens_path()).unwrap();
    let tokens_i32: Vec<i32> = goldens["prefill_tokens"].as_i32().unwrap().to_vec();
    let want = goldens["prefill_logits_fp"].as_f32().unwrap();

    let mcfg = engine.artifacts.model;
    let weights = Weights::load(engine.artifacts.weights_path(), &mcfg).unwrap();
    let ecfg = EngineConfig {
        method: Method::Fp,
        scheme: Scheme::FP,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&weights, &mcfg, &ecfg, None, None).unwrap();
    let tokens: Vec<u32> = tokens_i32.iter().map(|&t| t as u32).collect();
    let logits = model.forward_full(&tokens, None);
    assert_eq!(logits.data.len(), want.len());
    let mut worst = 0.0f32;
    for (&g, &w) in logits.data.iter().zip(want) {
        worst = worst.max((g - w).abs());
    }
    // independent implementations (different accumulation order): small
    // but nonzero drift allowed
    assert!(worst < 5e-2, "rust-vs-pjrt fp: max err {worst}");
    eprintln!("rust engine vs pjrt fp: max err {worst}");
}

#[test]
fn rust_engine_rtn_matches_pjrt_rtn() {
    // RTN weights are calibration-free, so the engines must agree up to
    // float-association noise (borderline INT4 rounds).
    need_artifacts!();
    let engine = PjrtEngine::new(artifacts_root()).unwrap();
    let goldens = read_rrsw(engine.artifacts.goldens_path()).unwrap();
    let tokens_i32: Vec<i32> = goldens["prefill_tokens"].as_i32().unwrap().to_vec();
    let want = goldens["prefill_logits_rtn"].as_f32().unwrap();
    let mcfg = engine.artifacts.model;
    let weights = Weights::load(engine.artifacts.weights_path(), &mcfg).unwrap();
    let ecfg = EngineConfig {
        method: Method::Rtn,
        scheme: Scheme::A4W4KV4,
        gptq: false,
        ..Default::default()
    };
    let model = QuantModel::prepare(&weights, &mcfg, &ecfg, None, None).unwrap();
    let tokens: Vec<u32> = tokens_i32.iter().map(|&t| t as u32).collect();
    let logits = model.forward_full(&tokens, None);
    // Quantized nets amplify float-association drift: borderline INT4
    // rounds flip between implementations and cascade, so two *correct*
    // engines agree statistically, not bitwise.  Quality-level checks:
    // high logit correlation + high next-token (top-1) agreement.
    let corr = correlation(&logits.data, want);
    let agree = top1_agreement(&logits.data, want, mcfg.vocab);
    assert!(corr > 0.9, "rust rtn vs pjrt rtn corr {corr}");
    assert!(agree > 0.9, "rust rtn vs pjrt rtn top-1 agreement {agree}");
    eprintln!("rust engine vs pjrt rtn: corr {corr} top1 {agree}");
}

fn top1_agreement(a: &[f32], b: &[f32], vocab: usize) -> f32 {
    let n = a.len() / vocab;
    let mut hits = 0;
    for i in 0..n {
        let ra = &a[i * vocab..(i + 1) * vocab];
        let rb = &b[i * vocab..(i + 1) * vocab];
        if rrs::linalg::argmax(ra) == rrs::linalg::argmax(rb) {
            hits += 1;
        }
    }
    hits as f32 / n as f32
}

#[test]
fn rust_engine_rrs_correlates_with_pjrt_rrs() {
    // GPTQ calibration differs slightly (python uses its own windows), so
    // compare correlation rather than allclose.
    need_artifacts!();
    let engine = PjrtEngine::new(artifacts_root()).unwrap();
    let goldens = read_rrsw(engine.artifacts.goldens_path()).unwrap();
    let tokens_i32: Vec<i32> = goldens["prefill_tokens"].as_i32().unwrap().to_vec();
    let want = goldens["prefill_logits_rrs"].as_f32().unwrap();

    let mcfg = engine.artifacts.model;
    let weights = Weights::load(engine.artifacts.weights_path(), &mcfg).unwrap();
    // same calibration protocol as python aot.py: 8 windows of 64 from val
    let val = engine.artifacts.val_text().unwrap();
    let val_toks = rrs::model::tokenizer::encode(&val);
    let calib: Vec<u32> =
        (0..8).flat_map(|i| val_toks[i * 64..i * 64 + 64].to_vec()).collect();
    let ecfg = EngineConfig {
        method: Method::Rrs,
        scheme: Scheme::A4W4KV4,
        group: 128,
        gptq: true,
        ..Default::default()
    };
    let model =
        QuantModel::prepare(&weights, &mcfg, &ecfg, Some(&calib), None).unwrap();
    let tokens: Vec<u32> = tokens_i32.iter().map(|&t| t as u32).collect();
    let logits = model.forward_full(&tokens, None);
    // see rust_engine_rtn_matches_pjrt_rtn for why this is statistical
    let corr = correlation(&logits.data, want);
    let agree = top1_agreement(&logits.data, want, mcfg.vocab);
    assert!(corr > 0.9, "rust rrs vs pjrt rrs corr {corr}");
    assert!(agree > 0.9, "rust rrs vs pjrt rrs top-1 agreement {agree}");
    eprintln!("rust engine vs pjrt rrs: corr {corr} top1 {agree}");
}

fn correlation(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len() as f32;
    let ma = a.iter().sum::<f32>() / n;
    let mb = b.iter().sum::<f32>() / n;
    let (mut num, mut da, mut db) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        num += (x - ma) * (y - mb);
        da += (x - ma) * (x - ma);
        db += (y - mb) * (y - mb);
    }
    num / (da.sqrt() * db.sqrt() + 1e-12)
}
